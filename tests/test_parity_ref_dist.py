"""Reference <-> distributed engine parity.

The shard_map production engine (core/distributed.py, ring mode) and the
paper-faithful reference engine (core/inference.py::diffusion_infer under
the constant-weight ring combiner) must compute the SAME iterates: same
adaptive step size on every model rank (the pmax'd safe mu), same per-agent
(nu, y) to tight tolerance on a forced 1x4 host mesh."""

import subprocess
import sys
import textwrap

import pytest

from conftest import REPO, subprocess_env


def _run(code: str, n_devices: int = 4, timeout: int = 900):
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=subprocess_env(n_devices), cwd=str(REPO),
        capture_output=True, text=True, timeout=timeout,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-4000:]}"
    return proc.stdout


def test_kernel_interpret_auto_detects_backend():
    """Default (None) resolves per backend: interpret only on CPU, compiled
    elsewhere; explicit booleans always win."""
    import jax

    from repro.core.distributed import DistConfig, resolve_kernel_interpret

    assert DistConfig().kernel_interpret is None
    assert resolve_kernel_interpret(None) is (jax.default_backend() == "cpu")
    assert resolve_kernel_interpret(True) is True
    assert resolve_kernel_interpret(False) is False


@pytest.mark.slow
def test_ring_parity_and_identical_mu():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.conjugates import make_task
        from repro.core.distributed import DistributedSparseCoder, DistConfig, make_debug_mesh
        from repro.core.dictionary import blocks_from_full
        from repro.core.inference import DiffusionConfig, diffusion_infer, safe_diffusion_mu
        from repro.core import topology as topo

        res, reg = make_task("sparse_svd", gamma=0.05, delta=0.1)
        N = 4
        mesh = make_debug_mesh(model=N, data=1)   # the forced 1x4 host mesh
        M, K, B = 16, 32, 4
        W = jax.random.normal(jax.random.PRNGKey(1), (M, K))
        W = W / jnp.linalg.norm(W, axis=0)
        x = jax.random.normal(jax.random.PRNGKey(2), (B, M))
        W_blocks = blocks_from_full(W, N)

        # Metropolis weights on a cycle = the constant-weight [1/3,1/3,1/3]
        # ring combiner the ppermute path realizes.
        A = topo.make_topology("ring_metropolis", N)
        np.testing.assert_allclose(A, topo.ring_weights(N, 1.0/3.0), atol=1e-12)

        coder = DistributedSparseCoder(
            mesh, res, reg, DistConfig(mode="ring", iters=300, mu=-1.0, beta=1.0/3.0))
        Ws, xs = coder.shard(W, x)

        # 1) every model rank reports the IDENTICAL adaptive mu, and it equals
        #    the reference max-over-blocks bound.
        mus = np.asarray(coder.adaptive_mu(Ws))
        assert mus.shape == (N,)
        assert float(np.ptp(mus)) == 0.0, mus
        mu_ref = float(safe_diffusion_mu(res, reg, W_blocks))
        assert abs(float(mus[0]) - mu_ref) < 1e-7 * mu_ref, (mus[0], mu_ref)

        # 2) per-agent (nu, y) parity with the reference diffusion engine.
        nu_ref, y_ref, _ = diffusion_infer(
            res, reg, W_blocks, x, jnp.asarray(A, jnp.float32),
            jnp.ones((N,), jnp.float32), DiffusionConfig(iters=300),
            mu=jnp.asarray(mu_ref, x.dtype))
        nu_d, y_d = coder.solve_per_agent(Ws, xs)
        nu_err = float(jnp.max(jnp.abs(jnp.asarray(nu_d) - nu_ref)))
        y_err = float(jnp.max(jnp.abs(jnp.asarray(y_d) - y_ref)))
        print("nu_err", nu_err, "y_err", y_err)
        assert nu_err < 1e-4, nu_err
        assert y_err < 1e-4, y_err

        # 3) the default solve()'s concatenated y matches the reference's
        #    per-agent blocks laid side by side.
        _, y_flat = coder.solve(Ws, xs)
        y_ref_flat = jnp.moveaxis(y_ref, 0, 1).reshape(B, K)
        assert float(jnp.max(jnp.abs(jnp.asarray(y_flat) - y_ref_flat))) < 1e-4
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_graph_mode_parity_with_reference_engine():
    """mode="graph" under the erdos and ring_metropolis Metropolis combiners
    (the paper's Sec.-IV-B regime) matches diffusion_infer run with the
    IDENTICAL A to 1e-4 on the 1x4 debug mesh — the ppermute schedule
    compiled from A computes the same iterates as the dense reference
    combine."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.conjugates import make_task
        from repro.core.distributed import DistributedSparseCoder, DistConfig, make_debug_mesh
        from repro.core.dictionary import blocks_from_full
        from repro.core.inference import DiffusionConfig, diffusion_infer, safe_diffusion_mu
        from repro.core import topology as topo

        res, reg = make_task("sparse_svd", gamma=0.05, delta=0.1)
        N = 4
        mesh = make_debug_mesh(model=N, data=1)
        M, K, B = 16, 32, 4
        W = jax.random.normal(jax.random.PRNGKey(1), (M, K))
        W = W / jnp.linalg.norm(W, axis=0)
        x = jax.random.normal(jax.random.PRNGKey(2), (B, M))
        W_blocks = blocks_from_full(W, N)
        mu_ref = float(safe_diffusion_mu(res, reg, W_blocks))

        for topology in ["erdos", "ring_metropolis"]:
            coder = DistributedSparseCoder(
                mesh, res, reg, DistConfig(mode="graph", iters=300, mu=-1.0,
                                           topology=topology, topology_seed=7))
            A = coder.combiner()
            assert topo.is_doubly_stochastic(A), topology
            Ws, xs = coder.shard(W, x)

            # graph mode uses the same pmax'd safe step as the ring family.
            mus = np.asarray(coder.adaptive_mu(Ws))
            assert float(np.ptp(mus)) == 0.0, (topology, mus)
            assert abs(float(mus[0]) - mu_ref) < 1e-7 * mu_ref

            nu_ref, y_ref, _ = diffusion_infer(
                res, reg, W_blocks, x, jnp.asarray(A, jnp.float32),
                jnp.ones((N,), jnp.float32), DiffusionConfig(iters=300),
                mu=jnp.asarray(mu_ref, x.dtype))
            nu_d, y_d = coder.solve_per_agent(Ws, xs)
            nu_err = float(jnp.max(jnp.abs(jnp.asarray(nu_d) - nu_ref)))
            y_err = float(jnp.max(jnp.abs(jnp.asarray(y_d) - y_ref)))
            print(topology, "nu_err", nu_err, "y_err", y_err)
            assert nu_err < 1e-4, (topology, nu_err)
            assert y_err < 1e-4, (topology, y_err)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_graph_tv_parity_with_reference_engine():
    """mode="graph_tv" under an alternating ring/torus schedule (and an
    erdos_resampled one) matches diffusion_infer run with the IDENTICAL
    time-varying callable A_t to 1e-4 on the 1x4 debug mesh: the lax.switch
    over per-step ppermute schedules computes the same iterates as the dense
    per-iteration combine.  Also asserts the schedule determinism contract
    at the engine level: two constructions (and two grown() coders) with the
    same topology_seed run the identical combiner sequence."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.conjugates import make_task
        from repro.core.distributed import DistributedSparseCoder, DistConfig, make_debug_mesh
        from repro.core.dictionary import blocks_from_full
        from repro.core.inference import DiffusionConfig, diffusion_infer, safe_diffusion_mu
        from repro.core import topology as topo

        res, reg = make_task("sparse_svd", gamma=0.05, delta=0.1)
        N = 4
        mesh = make_debug_mesh(model=N, data=1)
        M, K, B = 16, 32, 4
        W = jax.random.normal(jax.random.PRNGKey(1), (M, K))
        W = W / jnp.linalg.norm(W, axis=0)
        x = jax.random.normal(jax.random.PRNGKey(2), (B, M))
        W_blocks = blocks_from_full(W, N)
        mu_ref = float(safe_diffusion_mu(res, reg, W_blocks))

        for spec, period in [("alternating:ring_metropolis,torus", 2),
                             ("erdos_resampled", 3)]:
            cfg = DistConfig(mode="graph_tv", iters=300, mu=-1.0,
                             topology_schedule=spec, schedule_period=period,
                             topology_seed=7)
            coder = DistributedSparseCoder(mesh, res, reg, cfg)
            sched = coder.topology_schedule
            assert sched.period == period, (spec, sched.period)
            for A_t in sched.combiners:  # every step doubly stochastic
                assert topo.is_doubly_stochastic(A_t), spec

            # determinism: a second engine with the same seed runs the
            # IDENTICAL network sequence
            coder2 = DistributedSparseCoder(mesh, res, reg, cfg)
            for a, b in zip(coder.combiner_sequence(), coder2.combiner_sequence()):
                np.testing.assert_array_equal(a, b)

            Ws, xs = coder.shard(W, x)

            # graph_tv uses the same pmax'd globally-safe step as the
            # static ring/graph families.
            mus = np.asarray(coder.adaptive_mu(Ws))
            assert float(np.ptp(mus)) == 0.0, (spec, mus)
            assert abs(float(mus[0]) - mu_ref) < 1e-7 * mu_ref

            # parity under the IDENTICAL time-varying callable A_t.
            nu_ref, y_ref, _ = diffusion_infer(
                res, reg, W_blocks, x, sched.as_callable(),
                jnp.ones((N,), jnp.float32), DiffusionConfig(iters=300),
                mu=jnp.asarray(mu_ref, x.dtype))
            nu_d, y_d = coder.solve_per_agent(Ws, xs)
            nu_err = float(jnp.max(jnp.abs(jnp.asarray(nu_d) - nu_ref)))
            y_err = float(jnp.max(jnp.abs(jnp.asarray(y_d) - y_ref)))
            print(spec, "nu_err", nu_err, "y_err", y_err)
            assert nu_err < 1e-4, (spec, nu_err)
            assert y_err < 1e-4, (spec, y_err)

            # schedule-offset parity: solving at t0=1 equals the reference
            # running the shifted sequence A_{1}, A_{2}, ...
            fn = sched.as_callable()
            nu_ref1, _, _ = diffusion_infer(
                res, reg, W_blocks, x, (lambda t: fn(t + 1)),
                jnp.ones((N,), jnp.float32), DiffusionConfig(iters=300),
                mu=jnp.asarray(mu_ref, x.dtype))
            nu_d1, _ = coder.solve_per_agent(Ws, xs, t0=1)
            err1 = float(jnp.max(jnp.abs(jnp.asarray(nu_d1) - nu_ref1)))
            print(spec, "t0=1 err", err1)
            assert err1 < 1e-4, (spec, err1)

        # grown() determinism + neighborhood preservation at the engine
        # level: two grown coders agree, and erdos adjacencies keep the old
        # block (the grow-preserving sampler, not a wholesale resample).
        cfg = DistConfig(mode="graph_tv", iters=50, topology_schedule="erdos_resampled",
                         schedule_period=2, topology_seed=9)
        base = DistributedSparseCoder(mesh, res, reg, cfg)
        Wb = jax.device_put(W, jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec(None, "model")))
        g1, _ = base.grown(Wb, 2, jax.random.PRNGKey(0))
        g2, _ = base.grown(Wb, 2, jax.random.PRNGKey(1))  # key only seeds new atoms
        for a, b in zip(g1.combiner_sequence(), g2.combiner_sequence()):
            np.testing.assert_array_equal(a, b)
        for old, new in zip(base.topology_schedule.adjacencies,
                            g1.topology_schedule.adjacencies):
            np.testing.assert_array_equal(new[:N, :N], old)

        # static erdos growth is grow-preserving too
        scfg = DistConfig(mode="graph", iters=50, topology="erdos", topology_seed=3)
        sbase = DistributedSparseCoder(mesh, res, reg, scfg)
        sg, _ = sbase.grown(Wb, 2, jax.random.PRNGKey(0))
        np.testing.assert_array_equal(sg._adj[:N, :N], sbase._adj)
        sg2, _ = sbase.grown(Wb, 2, jax.random.PRNGKey(5))
        np.testing.assert_array_equal(sg._adj, sg2._adj)
        # and it shares the schedule path's seed stream: a static erdos
        # coder and its "fixed:erdos" time-varying wrapper grow to the
        # IDENTICAL network (same seed, step 0, same target size).
        fs = topo.make_topology_schedule(
            "fixed:erdos", N, seed=3).grown(N + 2)
        np.testing.assert_array_equal(sg._adj, fs.adjacencies[0])
        print("OK")
    """, n_devices=8)
    assert "OK" in out


@pytest.mark.slow
def test_hier_parity_with_reference_engine():
    """mode="hier" on a (2, 1, 4) debug mesh — two pods of four agents —
    matches diffusion_infer run under the dense Kronecker combiner
    A_pod (x) A_model to 1e-4: the intra-pod + inter-pod ppermute schedules
    composed inside one shard_map compute the same iterates as the dense
    (8, 8) reference combine over the pod-major flattened agent axis.
    Covers pod_gossip_every=2 (reference = the time-varying sequence
    alternating A_pod (x) A_model with I (x) A_model) including a t0
    phase offset, the pmax-over-BOTH-axes adaptive mu, hier growth
    determinism, and hier_q8 staying in a quantization-sized neighborhood.
    """
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.conjugates import make_task
        from repro.core.distributed import DistributedSparseCoder, DistConfig, make_debug_mesh
        from repro.core.dictionary import blocks_from_full
        from repro.core.inference import DiffusionConfig, diffusion_infer, safe_diffusion_mu
        from repro.core import topology as topo

        res, reg = make_task("sparse_svd", gamma=0.05, delta=0.1)
        PODS, N = 2, 4
        mesh = make_debug_mesh(model=N, data=1, pods=PODS)  # the (2,1,4) mesh
        M, K, B = 16, 32, 4
        W = jax.random.normal(jax.random.PRNGKey(1), (M, K))
        W = W / jnp.linalg.norm(W, axis=0)
        x = jax.random.normal(jax.random.PRNGKey(2), (B, M))
        # the flat reference network: PODS*N agents, pod-major atom blocks
        W_blocks = blocks_from_full(W, PODS * N)
        mu_ref = float(safe_diffusion_mu(res, reg, W_blocks))

        # -- pod hop every iteration: static Kronecker combiner ------------
        cfg = DistConfig(mode="hier", iters=300, mu=-1.0, topology="torus",
                         pod_topology="ring_metropolis", topology_seed=7)
        coder = DistributedSparseCoder(mesh, res, reg, cfg)
        ht = coder.hier_topology
        A = coder.combiner()
        assert A.shape == (PODS * N, PODS * N)
        np.testing.assert_allclose(A, np.kron(ht.A_pod, ht.A_model))
        assert topo.is_doubly_stochastic(A)

        Ws, xs = coder.shard(W, x)
        # adaptive mu pmax'd over BOTH axes: all 8 agents identical, equal
        # to the reference max-over-8-blocks bound.
        mus = np.asarray(coder.adaptive_mu(Ws))
        assert mus.shape == (PODS * N,)
        assert float(np.ptp(mus)) == 0.0, mus
        assert abs(float(mus[0]) - mu_ref) < 1e-7 * mu_ref, (mus[0], mu_ref)

        nu_ref, y_ref, _ = diffusion_infer(
            res, reg, W_blocks, x, jnp.asarray(A, jnp.float32),
            jnp.ones((PODS * N,), jnp.float32), DiffusionConfig(iters=300),
            mu=jnp.asarray(mu_ref, x.dtype))
        nu_d, y_d = coder.solve_per_agent(Ws, xs)
        nu_err = float(jnp.max(jnp.abs(jnp.asarray(nu_d) - nu_ref)))
        y_err = float(jnp.max(jnp.abs(jnp.asarray(y_d) - y_ref)))
        print("hier nu_err", nu_err, "y_err", y_err)
        assert nu_err < 1e-4, nu_err
        assert y_err < 1e-4, y_err

        # -- pod_gossip_every=2: reference = alternating dense sequence ----
        cfg2 = DistConfig(mode="hier", iters=300, mu=-1.0, topology="torus",
                          pod_topology="ring_metropolis", topology_seed=7,
                          pod_gossip_every=2)
        coder2 = DistributedSparseCoder(mesh, res, reg, cfg2)
        seq = coder2.combiner_sequence()
        assert len(seq) == 2
        np.testing.assert_allclose(seq[0], np.kron(ht.A_pod, ht.A_model))
        np.testing.assert_allclose(seq[1], np.kron(np.eye(PODS), ht.A_model))
        fn = coder2.hier_topology.as_callable()
        nu_ref2, _, _ = diffusion_infer(
            res, reg, W_blocks, x, fn,
            jnp.ones((PODS * N,), jnp.float32), DiffusionConfig(iters=300),
            mu=jnp.asarray(mu_ref, x.dtype))
        nu_d2, _ = coder2.solve_per_agent(Ws, xs)
        err2 = float(jnp.max(jnp.abs(jnp.asarray(nu_d2) - nu_ref2)))
        print("hier k=2 nu_err", err2)
        assert err2 < 1e-4, err2

        # schedule-offset parity: t0=1 starts on a no-hop iteration
        nu_ref3, _, _ = diffusion_infer(
            res, reg, W_blocks, x, (lambda t: fn(t + 1)),
            jnp.ones((PODS * N,), jnp.float32), DiffusionConfig(iters=300),
            mu=jnp.asarray(mu_ref, x.dtype))
        nu_d3, _ = coder2.solve_per_agent(Ws, xs, t0=1)
        err3 = float(jnp.max(jnp.abs(jnp.asarray(nu_d3) - nu_ref3)))
        print("hier k=2 t0=1 nu_err", err3)
        assert err3 < 1e-4, err3

        # -- hier_q8: int8 on the pod hop only — stays in a quantization-
        #    sized neighborhood of the full-precision iterates
        cfgq = DistConfig(mode="hier_q8", iters=300, mu=-1.0, topology="torus",
                          pod_topology="ring_metropolis", topology_seed=7)
        coderq = DistributedSparseCoder(mesh, res, reg, cfgq)
        nu_q, _ = coderq.solve_per_agent(Ws, xs)
        q_dev = float(jnp.max(jnp.abs(jnp.asarray(nu_q) - nu_ref)))
        print("hier_q8 deviation", q_dev)
        assert np.isfinite(np.asarray(nu_q)).all()
        assert q_dev < 1e-2, q_dev

        # -- growth: model axis only, deterministic, shard-preserving ------
        g1, W2 = coder.grown(Ws, 1, jax.random.PRNGKey(0))
        g2, _ = coder.grown(Ws, 1, jax.random.PRNGKey(9))  # key only seeds atoms
        np.testing.assert_array_equal(g1.hier_topology.A_pod, ht.A_pod)
        for a, b in zip(g1.combiner_sequence(), g2.combiner_sequence()):
            np.testing.assert_array_equal(a, b)
        # pod-major interleave keeps every old (pod, model) shard in place
        kb = K // (PODS * N)
        W2h = np.asarray(jax.device_get(W2))
        Wh = np.asarray(W)
        np.testing.assert_array_equal(W2h[:, :N * kb], Wh[:, :N * kb])
        np.testing.assert_array_equal(
            W2h[:, (N + 1) * kb:(2 * N + 1) * kb], Wh[:, N * kb:])
        print("OK")
    """, n_devices=12)
    assert "OK" in out


# Every registry mode, pinned here so pytest can parametrize without
# importing jax at collection time; test_mu_modes_cover_registry asserts
# this tuple tracks MODE_REGISTRY.
_ALL_MODES = (
    "chain", "exact", "exact_fista", "graph", "graph_async", "graph_q8",
    "graph_tv", "graph_tv_q8", "hier", "hier_q8", "push", "push_q8",
    "ring", "ring_async", "ring_q8",
)


def test_mu_modes_cover_registry():
    from repro.core.distributed import MODES

    assert tuple(sorted(MODES)) == _ALL_MODES


@pytest.mark.slow
@pytest.mark.parametrize("mode", _ALL_MODES)
def test_adaptive_mu_identical_across_ranks(mode):
    """The mu regression, per registry mode: exact modes psum a shared
    bound, ring/graph modes pmax the per-shard bounds, hier/chain modes
    pmax over ALL agent axes of the multi-level network — every rank
    reports the identical adaptive step size.  (The static counterpart is
    tools/analyze's step-size-replication rule, which proves this on the
    jaxpr for any mesh; this test confirms it numerically on a real 4-way
    mesh for the mode under test.)"""
    flat = mode not in ("hier", "hier_q8", "chain")
    if mode in ("push", "push_q8"):
        # the directed row-stochastic-only combiner: the mu pmax must hold
        # even when the gossip itself is asymmetric ratio consensus
        setup = """
        mesh = make_debug_mesh(model=4, data=1)
        cfg = DistConfig(mode=MODE, iters=10, mu=-1.0, topology="distar")
        spec = jax.sharding.PartitionSpec(None, "model")
        """
    elif flat:
        setup = """
        mesh = make_debug_mesh(model=4, data=1)
        cfg = DistConfig(mode=MODE, iters=10, mu=-1.0)
        spec = jax.sharding.PartitionSpec(None, "model")
        """
    elif mode == "chain":
        # two-level Kronecker chain (pod x model) with a q8 outer hop:
        # the mu reduction must span both levels regardless of wire format
        setup = """
        mesh = make_debug_mesh(model=2, data=1, pods=2)
        cfg = DistConfig(mode=MODE, iters=10, mu=-1.0, topology_seed=7,
                         levels="ring_metropolis,ring_metropolis:2:q8")
        spec = jax.sharding.PartitionSpec(None, ("pod", "model"))
        """
    else:
        setup = """
        mesh = make_debug_mesh(model=2, data=1, pods=2)
        cfg = DistConfig(mode=MODE, iters=10, mu=-1.0,
                         pod_topology="ring_metropolis", pod_gossip_every=2)
        spec = jax.sharding.PartitionSpec(None, ("pod", "model"))
        """
    out = _run(f"""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.conjugates import make_task
        from repro.core.distributed import DistributedSparseCoder, DistConfig, make_debug_mesh

        MODE = {mode!r}
        res, reg = make_task("nmf", gamma=0.05, delta=0.1)
        W = jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (24, 32)))
        W = W / jnp.linalg.norm(W, axis=0)
{textwrap.indent(textwrap.dedent(setup), "        ")}
        coder = DistributedSparseCoder(mesh, res, reg, cfg)
        Ws = jax.device_put(W, jax.sharding.NamedSharding(mesh, spec))
        mus = np.asarray(coder.adaptive_mu(Ws))
        print(MODE, mus)
        assert mus.shape == (4,), mus.shape
        assert float(np.ptp(mus)) == 0.0, (MODE, mus)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_chain_3level_parity_with_reference_engine():
    """mode="chain" with the acceptance 3-level chain (chip x pod x rack,
    strides 1/2/4) on the (2, 2, 1, 2) debug mesh — eight agents, axes
    ("pod2", "pod", "data", "model") — matches diffusion_infer run under
    the dense stride-gated Kronecker-sequence callable
    (KroneckerChain.as_callable) to 1e-4.  The q8-on-both-outer-hops
    variant stays in a quantization-sized neighborhood, and the
    stale-outermost variant matches an explicit one-step-delayed dense
    reference (off-diagonal outer contributions computed from the inner
    combine of the PREVIOUS outer firing, zeros before the first) to
    1e-4."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.conjugates import make_task
        from repro.core.distributed import DistributedSparseCoder, DistConfig, make_debug_mesh
        from repro.core.dictionary import blocks_from_full
        from repro.core.inference import (
            DiffusionConfig, agent_grad, diffusion_infer, safe_diffusion_mu)
        from repro.core import topology as topo

        res, reg = make_task("sparse_svd", gamma=0.05, delta=0.1)
        mesh = make_debug_mesh(model=2, data=1, pods=2, outer=(2,))
        NTOT = 8
        M, K, B, ITERS = 16, 32, 4, 300
        W = jax.random.normal(jax.random.PRNGKey(1), (M, K))
        W = W / jnp.linalg.norm(W, axis=0)
        x = jax.random.normal(jax.random.PRNGKey(2), (B, M))
        # flat reference network: 8 agents, outermost-major atom blocks
        W_blocks = blocks_from_full(W, NTOT)
        mu_ref = float(safe_diffusion_mu(res, reg, W_blocks))
        ones = jnp.ones((NTOT,), jnp.float32)

        # -- fp32 chain, strides 1/2/4: dense Kronecker-sequence parity ----
        cfg = DistConfig(mode="chain", iters=ITERS, mu=-1.0, topology_seed=7,
                         levels="ring_metropolis,ring_metropolis:2,full:4")
        coder = DistributedSparseCoder(mesh, res, reg, cfg)
        chain = coder.chain
        assert chain.ns == (2, 2, 2) and chain.period == 4
        assert coder.schedule_period == 4 and coder.is_time_varying
        A0 = coder.combiner_sequence()[0]
        np.testing.assert_allclose(
            A0, np.kron(chain.combiners[2],
                        np.kron(chain.combiners[1], chain.combiners[0])))
        assert topo.is_doubly_stochastic(np.asarray(A0))

        Ws, xs = coder.shard(W, x)
        # adaptive mu pmax'd over ALL THREE agent axes: identical everywhere
        mus = np.asarray(coder.adaptive_mu(Ws))
        assert mus.shape == (NTOT,)
        assert float(np.ptp(mus)) == 0.0, mus
        assert abs(float(mus[0]) - mu_ref) < 1e-7 * mu_ref

        nu_ref, y_ref, _ = diffusion_infer(
            res, reg, W_blocks, x, chain.as_callable(), ones,
            DiffusionConfig(iters=ITERS), mu=jnp.asarray(mu_ref, x.dtype))
        nu_d, y_d = coder.solve_per_agent(Ws, xs)
        nu_err = float(jnp.max(jnp.abs(jnp.asarray(nu_d) - nu_ref)))
        y_err = float(jnp.max(jnp.abs(jnp.asarray(y_d) - y_ref)))
        print("chain fp32 nu_err", nu_err, "y_err", y_err)
        assert nu_err < 1e-4, nu_err
        assert y_err < 1e-4, y_err

        # t0 phase offset: engine at t0=1 == reference on the shifted seq
        fn = chain.as_callable()
        nu_ref1, _, _ = diffusion_infer(
            res, reg, W_blocks, x, (lambda t: fn(t + 1)), ones,
            DiffusionConfig(iters=ITERS), mu=jnp.asarray(mu_ref, x.dtype))
        nu_d1, _ = coder.solve_per_agent(Ws, xs, t0=1)
        err1 = float(jnp.max(jnp.abs(jnp.asarray(nu_d1) - nu_ref1)))
        print("chain fp32 t0=1 nu_err", err1)
        assert err1 < 1e-4, err1

        # -- q8 on both outer hops: quantization-sized neighborhood --------
        cfgq = DistConfig(mode="chain", iters=ITERS, mu=-1.0, topology_seed=7,
                          levels="ring_metropolis,ring_metropolis:2:q8,full:4:q8")
        coderq = DistributedSparseCoder(mesh, res, reg, cfgq)
        nu_q, _ = coderq.solve_per_agent(Ws, xs)
        q_dev = float(jnp.max(jnp.abs(jnp.asarray(nu_q) - nu_ref)))
        print("chain q8 deviation", q_dev)
        assert np.isfinite(np.asarray(nu_q)).all()
        assert q_dev < 1e-2, q_dev

        # -- stale outermost hop: explicit one-step-delayed reference ------
        cfgs = DistConfig(mode="chain", iters=ITERS, mu=-1.0, topology_seed=7,
                          levels="ring_metropolis,ring_metropolis:2,full:4:stale")
        coders = DistributedSparseCoder(mesh, res, reg, cfgs)
        sch = coders.chain
        f_out = sch.combiners[2]
        D = np.diag(np.diag(f_out))          # self weights: current value
        Off = f_out - D                      # neighbor weights: delayed value
        n_in = 4                             # agents under each outer group
        I_in = np.eye(n_in)
        k_out = 4                            # outer stride

        def inner_at(t):
            F0 = sch.combiners[0]
            F1 = sch.combiners[1] if t % 2 == 0 else np.eye(2)
            return np.kron(np.eye(2), np.kron(F1, F0))

        grad_all = jax.vmap(
            lambda W_k, nu_k: agent_grad(
                res, reg, W_k, nu_k, x, jnp.asarray(1.0, x.dtype),
                NTOT, jnp.asarray(float(NTOT), x.dtype)))
        mu = jnp.asarray(mu_ref, x.dtype)
        nu = jnp.zeros((NTOT,) + x.shape, x.dtype)
        u_sent = jnp.zeros_like(nu)          # zeros before the first firing
        for t in range(ITERS):
            g = grad_all(W_blocks, nu)
            psi = nu - mu * g
            u = jnp.tensordot(
                jnp.asarray(inner_at(t).T, x.dtype), psi, axes=1)
            if t % k_out == 0:
                comb = (
                    jnp.tensordot(jnp.asarray(np.kron(D, I_in).T, x.dtype),
                                  u, axes=1)
                    + jnp.tensordot(jnp.asarray(np.kron(Off, I_in).T, x.dtype),
                                    u_sent, axes=1)
                )
                u_sent = u                   # messages shipped THIS firing
            else:
                comb = u
            nu = res.project_dual(comb)
        nu_s, _ = coders.solve_per_agent(Ws, xs)
        s_err = float(jnp.max(jnp.abs(jnp.asarray(nu_s) - nu)))
        print("chain stale-outermost nu_err", s_err)
        assert s_err < 1e-4, s_err
        print("OK")
    """, n_devices=8)
    assert "OK" in out
