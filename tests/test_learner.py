"""DictionaryLearner end-to-end: learning reduces the objective, recovers a
planted dictionary, supports network growth, and the distributed update
matches the structure of Eq. 51."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.baselines import MairalConfig, MairalLearner
from repro.core.conjugates import make_task
from repro.core.dictionary import (
    blocks_from_full,
    full_from_blocks,
    init_dictionary,
    project_nonneg_unit_cols,
    project_unit_cols,
)
from repro.core.learner import DictionaryLearner, LearnerConfig
from repro.data.synthetic import sparse_stream


def planted_data(m=16, k_true=24, n=512, sparsity=3, seed=0, nonneg=False):
    """x = W0 y with y k-sparse — the recoverable regime (the shared
    planted model from repro.data.synthetic)."""
    X, W0 = sparse_stream(
        n, m=m, k_true=k_true, sparsity=sparsity, nonneg=nonneg, seed=seed,
        return_dictionary=True,
    )
    return jnp.asarray(X), jnp.asarray(W0)


def test_blocks_roundtrip():
    W = init_dictionary(jax.random.PRNGKey(0), 10, 12)
    blocks = blocks_from_full(W, 4)
    assert blocks.shape == (4, 10, 3)
    np.testing.assert_array_equal(np.asarray(full_from_blocks(blocks)), np.asarray(W))
    with pytest.raises(ValueError):
        blocks_from_full(W, 5)


def test_projections():
    X = jax.random.normal(jax.random.PRNGKey(0), (6, 8)) * 3
    P1 = project_unit_cols(X)
    assert float(jnp.max(jnp.linalg.norm(P1, axis=0))) <= 1.0 + 1e-6
    # columns already inside the ball are untouched
    Xs = X / (jnp.linalg.norm(X, axis=0, keepdims=True) * 2)
    np.testing.assert_allclose(np.asarray(project_unit_cols(Xs)), np.asarray(Xs), rtol=1e-6)
    P2 = project_nonneg_unit_cols(X)
    assert bool(jnp.all(P2 >= 0))
    assert float(jnp.max(jnp.linalg.norm(P2, axis=0))) <= 1.0 + 1e-6


@pytest.mark.parametrize("engine", ["exact", "fista", "diffusion"])
def test_objective_decreases(engine):
    X, _ = planted_data()
    cfg = LearnerConfig(
        m=16, k=32, n_agents=8, task="sparse_svd", gamma=0.05, delta=0.1,
        mu=-1.0, inference_iters=400 if engine == "diffusion" else 200,
        engine=engine, mu_w=0.1, topology="erdos", seed=0,
    )
    learner = DictionaryLearner(cfg)
    state = learner.init_state()
    objs = []
    for i in range(12):
        state, metrics = learner.fit_batch(state, X[i * 16 : (i + 1) * 16])
        objs.append(float(metrics.primal_obj))
    assert objs[-1] < objs[0], objs
    assert all(np.isfinite(objs))


def test_recovers_planted_atoms():
    """After training, most planted atoms should have a close learned atom
    (|cos| > 0.9) — the classical dictionary-recovery sanity check.  Needs a
    sparsity-matched gamma (gamma=0.25, delta=0.05 gives ~0.18 nonzeros,
    close to the planted 3/24)."""
    X, W0 = planted_data(n=1024)
    cfg = LearnerConfig(
        m=16, k=32, n_agents=8, task="sparse_svd", gamma=0.25, delta=0.05,
        mu=-1.0, inference_iters=200, engine="fista", mu_w=0.5, seed=1,
    )
    learner = DictionaryLearner(cfg)
    state = learner.init_state()
    for epoch in range(15):
        state, _ = learner.fit(state, X, batch_size=16)
    W = np.asarray(learner.dictionary(state))
    cos = np.abs(W0.T @ W)  # (k_true, k)
    hits = (cos.max(axis=1) > 0.9).mean()
    assert hits > 0.8, f"only {hits:.0%} of planted atoms recovered"


def test_fit_processes_streaming_tail():
    """fit() must not drop the final partial minibatch — in the paper's
    single-pass streaming regime every sample is seen exactly once."""
    X, _ = planted_data(n=10)
    cfg = LearnerConfig(m=16, k=16, n_agents=2, engine="exact", inference_iters=20)
    learner = DictionaryLearner(cfg)
    state = learner.init_state()
    # 10 samples / batch 4 -> two full batches + a tail of 2 = 3 steps
    state, metrics = learner.fit(state, X, batch_size=4)
    assert int(state.step) == 3
    assert metrics is not None and np.isfinite(float(metrics.primal_obj))
    # fewer samples than one batch: the whole input is the tail (1 step)
    state2 = learner.init_state()
    state2, metrics2 = learner.fit(state2, X[:3], batch_size=8)
    assert int(state2.step) == 1
    assert metrics2 is not None

    # the tail is processed as a (smaller) batch: fit == manual batch loop
    state_a = learner.init_state()
    state_a, _ = learner.fit(state_a, X, batch_size=4)
    state_b = learner.init_state()
    for xb in (X[0:4], X[4:8], X[8:10]):
        state_b, _ = learner.fit_batch(state_b, xb)
    np.testing.assert_allclose(
        np.asarray(learner.dictionary(state_a)),
        np.asarray(learner.dictionary(state_b)), rtol=1e-5, atol=1e-6,
    )


def test_network_growth_preserves_atoms():
    cfg = LearnerConfig(m=8, k=16, n_agents=8, engine="exact", inference_iters=50)
    learner = DictionaryLearner(cfg)
    state = learner.init_state()
    W_before = learner.dictionary(state)
    learner2, state2 = learner.expanded(state, extra_agents=4, key=jax.random.PRNGKey(9))
    assert learner2.cfg.n_agents == 12 and learner2.cfg.k == 24
    W_after = learner2.dictionary(state2)
    np.testing.assert_array_equal(np.asarray(W_after[:, :16]), np.asarray(W_before))


def test_dict_update_is_correlation_form():
    """Eq. 51: the update direction is exactly nu y^T (projected)."""
    from repro.core.dictionary import dict_update

    nu = jax.random.normal(jax.random.PRNGKey(0), (4, 8))
    y = jax.random.normal(jax.random.PRNGKey(1), (4, 6))
    W = init_dictionary(jax.random.PRNGKey(2), 8, 6) * 0.1  # strictly inside the ball
    mu_w = 1e-3
    W2 = dict_update(W, nu, y, mu_w)
    np.testing.assert_allclose(
        np.asarray(W2 - W), np.asarray(mu_w * nu.T @ y / 4), rtol=1e-4, atol=1e-6
    )


def test_mairal_baseline_learns():
    X, _ = planted_data(nonneg=False)
    _, reg = make_task("sparse_svd", gamma=0.05, delta=0.1)
    learner = MairalLearner(MairalConfig(m=16, k=32, gamma=0.05, delta=0.1), reg)
    state = learner.init_state()
    objs = []
    for i in range(16):
        state, obj = learner.fit_batch(state, X[i * 16 : (i + 1) * 16])
        objs.append(float(obj))
    assert objs[-1] < objs[0]
