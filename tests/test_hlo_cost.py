"""Validation of the trip-count-aware HLO cost analyzer (the roofline's
measurement instrument): scanned and unrolled lowerings of the same model
must yield (near-)identical costs, and totals must straddle the closed-form
model FLOPs sensibly."""

import dataclasses
import subprocess
import sys
import textwrap

import pytest

from conftest import REPO, subprocess_env


def _run(code: str, n_devices: int = 8, timeout: int = 900):
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=subprocess_env(n_devices), cwd=str(REPO),
        capture_output=True, text=True, timeout=timeout,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-4000:]}"
    return proc.stdout


@pytest.mark.slow
def test_scan_vs_unroll_costs_agree():
    out = _run("""
        import dataclasses, jax
        from repro.configs import get_smoke_config
        from repro.configs.base import ShapeConfig
        from repro.launch.mesh import make_mesh
        from repro.launch.hlo_cost import analyze_compiled
        from repro.optim import adamw
        from repro.runtime import steps as S

        mesh = make_mesh((2, 4), ("data", "model"))
        shape = ShapeConfig("smoke", 64, 4, "train")
        for arch in ("olmo_1b", "granite_moe_1b_a400m"):
            cfg = get_smoke_config(arch)
            costs = {}
            for scan in (True, False):
                c2 = dataclasses.replace(cfg, scan_layers=scan)
                comp = S.lower_train(c2, mesh, adamw(1e-3), shape).compile()
                costs[scan] = analyze_compiled(comp)
            f_ratio = costs[True].flops / costs[False].flops
            b_ratio = costs[True].bytes / costs[False].bytes
            print(arch, f_ratio, b_ratio)
            assert 0.85 < f_ratio < 1.15, (arch, f_ratio)
            assert 0.7 < b_ratio < 1.3, (arch, b_ratio)
            # collectives: scanned body x trips == unrolled occurrences
            c_ratio = (costs[True].coll_bytes + 1) / (costs[False].coll_bytes + 1)
            print(arch, "coll ratio", c_ratio)
            assert 0.8 < c_ratio < 1.25, (arch, c_ratio)
        print("OK")
    """)
    assert "OK" in out


@pytest.mark.slow
def test_flops_match_closed_form():
    """Trip-weighted HLO flops for a forward pass land within a sensible
    band around the closed-form 2*N*D."""
    out = _run("""
        import jax, jax.numpy as jnp
        from repro.configs import get_smoke_config
        from repro.launch.hlo_cost import analyze_compiled
        from repro.models import model as M
        from repro.models.layers import split_tree

        cfg = get_smoke_config("olmo_1b")
        params, _ = split_tree(M.init(cfg, jax.random.PRNGKey(0)))
        B, S = 4, 64
        batch = {"tokens": jnp.ones((B, S), jnp.int32)}
        comp = jax.jit(lambda p, b: M.forward(cfg, p, b)).lower(params, batch).compile()
        costs = analyze_compiled(comp)
        n = cfg.param_counts()["total"]
        model_flops = 2 * n * B * S
        ratio = costs.flops / model_flops
        print("ratio", ratio)
        # forward >= 2ND (embedding gather is free-ish; attention adds more);
        # anything in [0.9, 3] is sane for a tiny config where norms and
        # elementwise work are a visible fraction
        assert 0.9 < ratio < 3.0, ratio
        print("OK")
    """, n_devices=1)
    assert "OK" in out
