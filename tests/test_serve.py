"""Serving-loop integration: prefill -> cache merge -> greedy decode on a
multi-device mesh, for one arch per cache family."""

import subprocess
import sys
import textwrap

import pytest

from conftest import REPO, subprocess_env


def _run(args, n_devices=8, timeout=900):
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.serve", *args],
        env=subprocess_env(n_devices), cwd=str(REPO),
        capture_output=True, text=True, timeout=timeout,
    )
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr[-3000:]}"
    return proc.stdout


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["gemma_2b", "zamba2_1p2b", "xlstm_1p3b"])
def test_serve_loop(arch):
    out = _run(["--arch", arch, "--batch", "2", "--prompt-len", "16",
                "--gen", "8", "--mesh", "2x4"])
    assert "ms/token" in out
    assert "generated token ids" in out


@pytest.mark.slow
def test_serve_greedy_matches_forward():
    """Greedy decode from the serving loop equals argmax over the training
    forward's logits (teacher forcing the generated prefix)."""
    code = """
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_smoke_config
        from repro.models import model as M
        from repro.models.layers import split_tree

        cfg = get_smoke_config("gemma_2b")
        params, _ = split_tree(M.init(cfg, jax.random.PRNGKey(0)))
        B, P, G = 2, 12, 6
        toks = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0, cfg.vocab)

        # serving path
        logits, cache_p = M.prefill(cfg, params, {"tokens": toks})
        full_cache = M.init_cache(cfg, B, P + G)
        cache = jax.tree.map(
            lambda full, part: jax.lax.dynamic_update_slice(
                full, part.astype(full.dtype), (0,) * full.ndim),
            full_cache, cache_p)
        tok = jnp.argmax(logits[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
        gen = [tok]
        for i in range(G - 1):
            lg, cache = M.decode_step(cfg, params, cache, tok, jnp.asarray(P + i, jnp.int32))
            tok = jnp.argmax(lg[:, -1, :], axis=-1).astype(jnp.int32)[:, None]
            gen.append(tok)
        gen = jnp.concatenate(gen, axis=1)

        # teacher-forced forward over the same prefix+generation
        seq = jnp.concatenate([toks, gen], axis=1)
        logits_full, _ = M.forward(cfg, params, {"tokens": seq})
        greedy_full = jnp.argmax(logits_full[:, P - 1 : P + G - 1, :], axis=-1)
        match = float(jnp.mean((greedy_full == gen).astype(jnp.float32)))
        print("greedy agreement:", match)
        assert match == 1.0, match
        print("OK")
    """
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        env=subprocess_env(1), cwd=str(REPO),
        capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr[-3000:]
    assert "OK" in proc.stdout
