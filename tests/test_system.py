"""End-to-end behaviour tests for the paper's system: the full Algorithm 1
pipeline (distributed inference -> primal recovery -> local dictionary
update) reproduces the paper's qualitative claims C1-C4 (DESIGN.md §1) at
test scale, plus a dry-run entry-point smoke test."""

import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import REPO, subprocess_env
from repro.core import topology as topo
from repro.core.conjugates import make_task
from repro.core.inference import (
    DiffusionConfig,
    diffusion_infer,
    fista_infer,
    safe_diffusion_mu,
    snr_db,
)
from repro.core.learner import DictionaryLearner, LearnerConfig
from repro.data import synthetic as ds


def test_c1_convergence_snr_curve():
    """C1 (paper Fig. 4): agent SNR vs iteration climbs monotonically into
    the 40+ dB regime."""
    key = jax.random.PRNGKey(0)
    res, reg = make_task("sparse_svd", gamma=0.05, delta=0.1)
    from repro.core.dictionary import blocks_from_full, init_dictionary

    W = init_dictionary(key, 20, 32)
    Wb = blocks_from_full(W, 8)
    x = jax.random.normal(jax.random.PRNGKey(1), (20,))
    A = jnp.asarray(topo.make_topology("erdos", 8, seed=0), jnp.float32)
    # mu at 3% of the stability bound: the O(mu^2) bias floor sits above the
    # paper's 40-50 dB target (Fig. 4 regime; see Sec. IV-A on tuning mu).
    mu = 0.03 * safe_diffusion_mu(res, reg, Wb)
    nu_ref = fista_infer(res, reg, W, x, iters=800)
    _, _, traj = diffusion_infer(
        res, reg, Wb, x, A, jnp.ones((8,), jnp.float32),
        DiffusionConfig(iters=42000), record_every=7000, mu=mu,
    )
    snrs = [float(snr_db(nu_ref, traj[i][0])) for i in range(traj.shape[0])]
    assert snrs[-1] > 40.0, snrs
    assert all(b >= a - 1.0 for a, b in zip(snrs, snrs[1:])), snrs


def test_c2_distributed_matches_centralized_denoising():
    """C2 (paper Fig. 5): distributed learner's denoising PSNR within tol of
    the centralized Mairal baseline on the same data."""
    from repro.core.baselines import MairalConfig, MairalLearner
    from repro.core.denoise import denoise_image, psnr

    imgs = ds.synthetic_images(16, 40, seed=0)
    patches = jnp.asarray(ds.patch_dataset(imgs, patch=6, n_patches=3000, seed=1))

    cfg = LearnerConfig(m=36, k=72, n_agents=12, task="sparse_svd", gamma=0.2,
                        delta=0.05, mu=-1.0, inference_iters=200, engine="fista",
                        mu_w=0.5, seed=0)
    dist = DictionaryLearner(cfg)
    st = dist.init_state()
    for _ in range(2):
        st, _ = dist.fit(st, patches, batch_size=32)

    central = MairalLearner(
        MairalConfig(m=36, k=72, gamma=0.2, delta=0.05, seed=0), dist.reg
    )
    mst = central.init_state()
    for _ in range(2):
        mst, _ = central.fit(mst, patches, batch_size=32)

    clean = jnp.asarray(ds.synthetic_images(1, 40, seed=77)[0])
    noisy = jnp.asarray(ds.noisy_version(np.asarray(clean)[None], 0.15, seed=3)[0])
    p_dist = float(psnr(clean, denoise_image(dist, st, noisy, patch=6, stride=2)))

    # evaluate the centralized dictionary through the same denoising path
    st_c = st._replace(W_blocks=jnp.moveaxis(mst.W.reshape(36, 12, 6), 1, 0))
    p_cent = float(psnr(clean, denoise_image(dist, st_c, noisy, patch=6, stride=2)))
    p_noisy = float(psnr(clean, noisy))
    assert p_dist > p_noisy + 3.0
    # Mairal's sufficient-statistics BCD is more sample-efficient than the
    # paper's SGD-style update at this offline 3k-patch budget; the paper's
    # +0.2 dB parity holds at its 1M-patch scale. We assert within 1.8 dB
    # here and track the gap honestly (EXPERIMENTS.md §Claims C2).
    assert p_dist > p_cent - 1.8, f"dist {p_dist:.2f} vs central {p_cent:.2f}"


def test_c3_novel_document_auc_over_time_steps():
    """C3 (paper Tables III/IV): the online distributed detector sustains a
    high AUC across time steps while the dictionary grows."""
    from repro.core.detection import auc, exact_score

    ts = ds.topic_documents(m_vocab=120, n_topics=16, docs_per_step=150,
                            n_steps=3, topics_per_step=3, seed=1)
    cfg = LearnerConfig(m=120, k=40, n_agents=10, task="nmf", gamma=0.05,
                        delta=0.1, mu=-1.0, inference_iters=200, engine="fista",
                        mu_w=0.3, seed=0)
    learner = DictionaryLearner(cfg)
    state = learner.init_state()
    state, _ = learner.fit(state, jnp.asarray(ts.docs[0]), batch_size=16)

    aucs = []
    for s in range(1, 4):
        h = jnp.asarray(ts.docs[s])
        labels = np.isin(ts.labels[s], list(ts.novel_steps[s]))
        if labels.sum() == 0:
            continue
        nu = fista_infer(learner.res, learner.reg, learner.dictionary(state), h, iters=300)
        scores = np.asarray(
            exact_score(learner.res, learner.reg, learner.dictionary(state), nu, h)
        )
        aucs.append(auc(scores, labels))
        # incorporate the block + grow the network (paper: +10 atoms/step)
        learner, state = learner.expanded(state, extra_agents=2, key=jax.random.PRNGKey(s))
        state, _ = learner.fit(state, h, batch_size=16)
    assert len(aucs) >= 2
    assert np.mean(aucs) > 0.7, aucs


def test_c4_huber_more_robust_than_l2_under_outliers():
    """C4: with outlier-contaminated documents, the Huber residual detector
    degrades less than the l2 one."""
    from repro.core.detection import auc, exact_score
    from repro.core.inference import exact_infer

    ts = ds.topic_documents(m_vocab=100, n_topics=10, docs_per_step=150,
                            n_steps=1, topics_per_step=3, seed=5)
    train = np.asarray(ts.docs[0])
    rng = np.random.default_rng(0)
    spikes = rng.random(train.shape) < 0.01  # sparse gross corruption
    train_noisy = train + 5.0 * spikes
    train_noisy /= np.linalg.norm(train_noisy, axis=-1, keepdims=True)

    h = jnp.asarray(ts.docs[1])
    labels = np.isin(ts.labels[1], list(ts.novel_steps[1]))

    aucs = {}
    for task in ("nmf", "nmf_huber"):
        # the plain projected-gradient engine needs ~2000 iterations to
        # converge the dual here; an unconverged nu gives a garbage
        # dictionary and chance-level AUC for BOTH residuals
        cfg = LearnerConfig(m=100, k=30, n_agents=10, task=task, gamma=0.05,
                            delta=0.1, eta=0.2, mu=-1.0, inference_iters=2000,
                            engine="exact", mu_w=0.3, seed=0)
        learner = DictionaryLearner(cfg)
        state = learner.init_state()
        for _ in range(2):
            state, _ = learner.fit(state, jnp.asarray(train_noisy), batch_size=16)
        W = learner.dictionary(state)
        nu = exact_infer(learner.res, learner.reg, W, h, iters=2000)
        scores = np.asarray(exact_score(learner.res, learner.reg, W, nu, h))
        aucs[task] = auc(scores, labels)
    # measured: huber ~0.87 vs l2 ~0.55 under 1% spike corruption
    assert aucs["nmf_huber"] > 0.7, aucs
    assert aucs["nmf_huber"] >= aucs["nmf"] + 0.1, aucs


@pytest.mark.slow
def test_dryrun_entry_point():
    """The multi-pod dry-run CLI works end to end for one cheap cell (its own
    process owns the 512-device override)."""
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "olmo_1b", "--shape", "decode_32k", "--resume"],
        env={**subprocess_env(1), "PYTHONPATH": str(REPO / "src")},
        cwd=str(REPO), capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stdout + proc.stderr[-2000:]
    assert "olmo_1b x decode_32k" in proc.stdout or "skip-cached" in proc.stdout
