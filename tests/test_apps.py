"""Application-level integration tests: image denoising improves PSNR and
novel-document detection separates novel from known topics (paper Sec. IV)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.denoise import denoise_image, psnr
from repro.core.detection import auc, consensus_score, exact_score, roc_curve
from repro.core.learner import DictionaryLearner, LearnerConfig
from repro.core.inference import exact_infer, fista_infer
from repro.data import synthetic as ds


@pytest.fixture(scope="module")
def trained_denoiser():
    imgs = ds.synthetic_images(20, 48, seed=0)
    patches = ds.patch_dataset(imgs, patch=6, n_patches=4000, seed=1)
    cfg = LearnerConfig(
        m=36, k=72, n_agents=12, task="sparse_svd", gamma=0.08, delta=0.1,
        mu=-1.0, inference_iters=150, engine="fista", mu_w=0.1, seed=0,
    )
    learner = DictionaryLearner(cfg)
    state = learner.init_state()
    state, _ = learner.fit(state, jnp.asarray(patches), batch_size=32)
    return learner, state


def test_denoising_improves_psnr(trained_denoiser):
    learner, state = trained_denoiser
    clean = jnp.asarray(ds.synthetic_images(1, 48, seed=99)[0])
    noisy = jnp.asarray(ds.noisy_version(np.asarray(clean)[None], sigma=0.15, seed=5)[0])
    den = denoise_image(learner, state, noisy, patch=6, stride=2)
    p_noisy = float(psnr(clean, noisy))
    p_den = float(psnr(clean, den))
    assert p_den > p_noisy + 2.0, f"denoise {p_noisy:.2f} -> {p_den:.2f} dB"


def test_detection_scores_separate_topics():
    ts = ds.topic_documents(m_vocab=120, n_topics=16, docs_per_step=150,
                            n_steps=2, topics_per_step=3, seed=1)
    cfg = LearnerConfig(
        m=120, k=40, n_agents=10, task="nmf", gamma=0.05, delta=0.1,
        mu=-1.0, inference_iters=200, engine="fista", mu_w=0.3, seed=0,
    )
    learner = DictionaryLearner(cfg)
    state = learner.init_state()
    # train on step-0 docs (the known topics); two epochs tightens the fit
    for _ in range(2):
        state, _ = learner.fit(state, jnp.asarray(ts.docs[0]), batch_size=16)
    # score step-1 docs: novel topics should get higher scores
    h = jnp.asarray(ts.docs[1])
    labels = np.isin(ts.labels[1], list(ts.novel_steps[1]))
    nu = fista_infer(learner.res, learner.reg, learner.dictionary(state), h, iters=300)
    scores = np.asarray(exact_score(learner.res, learner.reg, learner.dictionary(state), nu, h))
    a = auc(scores, labels)
    assert a > 0.7, f"AUC {a:.3f}"


def test_consensus_score_matches_exact():
    """The scalar diffusion consensus (Eq. 63-66) converges to the exact
    aggregated dual value (up to the 1/N factor absorbed by the threshold)."""
    from repro.core import topology as topo
    from repro.core.conjugates import make_task
    from repro.core.dictionary import blocks_from_full, init_dictionary

    res, reg = make_task("nmf", gamma=0.05, delta=0.1)
    n, m, k = 8, 24, 32
    W = init_dictionary(jax.random.PRNGKey(0), m, k, nonneg=True)
    Wb = blocks_from_full(W, n)
    h = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (5, m)))
    nu = exact_infer(res, reg, W, h, iters=400)
    nu_agents = jnp.broadcast_to(nu, (n,) + nu.shape)
    A = jnp.asarray(topo.make_topology("erdos", n, seed=4), jnp.float32)
    # the scalar diffusion has an O(mu_g) bias under a sparse combiner, so a
    # small step + many (cheap, scalar) iterations gives the tight estimate
    g = consensus_score(res, reg, Wb, nu_agents, h, A, mu_g=0.02, iters=20000)
    target = exact_score(res, reg, W, nu, h) / n
    for agent in range(n):
        np.testing.assert_allclose(np.asarray(g[agent]), np.asarray(-target) * -1.0,
                                   rtol=5e-2, atol=1e-2)


def test_roc_and_auc_sanity():
    scores = np.array([0.9, 0.8, 0.7, 0.3, 0.2, 0.1])
    labels = np.array([1, 1, 1, 0, 0, 0])
    assert auc(scores, labels) == 1.0
    assert auc(-scores, labels) == 0.0
    assert abs(auc(np.random.default_rng(0).random(2000), np.random.default_rng(1).integers(0, 2, 2000)) - 0.5) < 0.05
    pfa, pd = roc_curve(scores, labels)
    assert pfa[0] <= pfa[-1] and (np.diff(pfa) >= -1e-9).all()
    assert pd.max() == 1.0
