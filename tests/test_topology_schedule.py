"""Time-varying combiner schedules (core/topology.TopologySchedule):
per-step validation, seeded determinism (the contract the time-varying
engine depends on: same topology_seed => identical network sequence, also
across grown() restarts), and the grow-preserving erdos sampler."""

import numpy as np
import pytest

from repro.core import topology as topo


# ---------------------------------------------------------------------------
# construction + per-step validation
# ---------------------------------------------------------------------------


def test_alternating_schedule_kinds_and_period():
    s = topo.make_topology_schedule("alternating:ring_metropolis,torus", 8)
    assert s.period == 2
    assert s.kinds == ("ring_metropolis", "torus")
    for a in s.combiners:
        assert topo.is_doubly_stochastic(a)
    # periodic indexing: at(t) = combiners[t % period]
    np.testing.assert_array_equal(s.at(0), s.combiners[0])
    np.testing.assert_array_equal(s.at(3), s.combiners[1])
    np.testing.assert_array_equal(s.at(4), s.combiners[0])


def test_alternating_default_kinds():
    s = topo.make_topology_schedule("alternating", 6)
    assert s.kinds == ("ring_metropolis", "torus")


def test_erdos_resampled_every_step_doubly_stochastic_and_distinct():
    s = topo.make_topology_schedule("erdos_resampled", 10, period=5, seed=3)
    assert s.period == 5
    for t, a in enumerate(s.combiners):
        assert topo.is_doubly_stochastic(a), t
        assert topo.is_connected(s.adjacencies[t])
    # resampling actually produces different graphs across the period
    assert len({a.tobytes() for a in s.adjacencies}) > 1


def test_fixed_schedule_degenerates_to_static():
    s = topo.make_topology_schedule("fixed:ring", 6, beta=0.25)
    assert s.period == 1
    np.testing.assert_allclose(s.combiners[0], topo.ring_weights(6, 0.25))
    # windowed mixing rate of a period-1 schedule IS the static mixing rate
    assert abs(s.windowed_mixing_rate() - topo.mixing_rate(s.combiners[0])) < 1e-12


def test_fixed_erdos_matches_static_graph_path():
    """'fixed:erdos' is the degenerate wrapper of the static mode='graph'
    erdos combiner: for the same (n, p, seed) it must sample the IDENTICAL
    graph (regression: a derived seed here silently changed the network)."""
    s = topo.make_topology_schedule("fixed:erdos", 9, p=0.4, seed=7)
    np.testing.assert_array_equal(
        s.adjacencies[0], topo.erdos_renyi_adjacency(9, p=0.4, seed=7)
    )
    np.testing.assert_allclose(
        s.combiners[0], topo.make_topology("erdos", 9, p=0.4, seed=7)
    )


def test_fixed_schedule_from_explicit_matrix():
    A = topo.ring_weights(5)
    s = topo.fixed_schedule(A)
    assert s.period == 1 and s.n == 5
    np.testing.assert_array_equal(s.at(7), A)
    # an explicit matrix has no generator, so growth is ALWAYS a designed
    # error — even with a kind label that happens to name a generator (the
    # label cannot prove A came from it, e.g. a non-default beta ring);
    # growable static schedules go through make_topology_schedule.
    for sched in (s, topo.fixed_schedule(topo.ring_weights(5, 0.25), kind="ring"),
                  topo.fixed_schedule(A, kind="erdos")):
        with pytest.raises(ValueError, match="explicit combiner"):
            sched.grown(8)
    g = topo.make_topology_schedule("fixed:ring", 5, beta=0.25).grown(8)
    np.testing.assert_allclose(g.combiners[0], topo.ring_weights(8, 0.25))


def test_static_and_fixed_erdos_growth_share_seed_stream():
    """The static mode='graph' erdos growth (distributed.py) and the
    'fixed:erdos' schedule's grown() must draw from the SAME seed stream
    (seed, step=0, n_new), so the degenerate-wrapper equivalence survives
    elastic growth (regression: the two paths used different streams)."""
    adj = topo.erdos_renyi_adjacency(6, p=0.5, seed=3)
    g = topo.make_topology_schedule("fixed:erdos", 6, p=0.5, seed=3).grown(9)
    np.testing.assert_array_equal(
        g.adjacencies[0],
        topo.erdos_renyi_grow(adj, 9, p=0.5, seed=topo.derive_seed(3, 0, 9)),
    )


def test_schedule_rejects_bad_spec_and_bad_combiner():
    with pytest.raises(KeyError):
        topo.make_topology_schedule("hypercube_sweep", 8)
    with pytest.raises(KeyError):
        topo.make_topology_schedule("alternating:ring,moebius", 8)
    with pytest.raises(KeyError):
        topo.make_topology_schedule("fixed:moebius", 8)
    with pytest.raises(KeyError):
        # the period is the `period` ARGUMENT, never spec syntax — silently
        # dropping a ':8' tail would run a different sequence than asked
        topo.make_topology_schedule("erdos_resampled:8", 8)
    # construction validates EVERY step doubly stochastic
    bad = np.array([[0.9, 0.2], [0.1, 0.8]])
    with pytest.raises(ValueError):
        topo.TopologySchedule(
            spec="fixed:bad", n=2, kinds=("bad",), combiners=(bad,),
            adjacencies=(None,),
        )
    with pytest.raises(ValueError):  # shape mismatch
        topo.TopologySchedule(
            spec="fixed:ring", n=3, kinds=("ring",),
            combiners=(topo.ring_weights(4),), adjacencies=(None,),
        )


def test_windowed_mixing_rate_window_product_is_doubly_stochastic():
    s = topo.make_topology_schedule("alternating:ring_metropolis,torus", 8)
    w = s.window_combiner()
    assert topo.is_doubly_stochastic(w)
    # the window of two combiners contracts at least as fast per step as the
    # slower of the two (submultiplicativity of sigma_2 for ds matrices)
    slow = max(topo.mixing_rate(a) for a in s.combiners)
    assert s.windowed_mixing_rate() <= slow + 1e-12


# ---------------------------------------------------------------------------
# determinism: same seed => identical sequence (constructions AND restarts)
# ---------------------------------------------------------------------------


def test_schedule_determinism_across_constructions():
    a = topo.make_topology_schedule("erdos_resampled", 9, period=4, seed=11)
    b = topo.make_topology_schedule("erdos_resampled", 9, period=4, seed=11)
    for x, y in zip(a.combiners, b.combiners):
        np.testing.assert_array_equal(x, y)
    c = topo.make_topology_schedule("erdos_resampled", 9, period=4, seed=12)
    assert any(
        x.tobytes() != y.tobytes() for x, y in zip(a.adjacencies, c.adjacencies)
    )


def test_grown_schedule_determinism_across_restarts():
    """grown() must be a pure function of (seed, step, n_new): re-deriving
    the grown sequence from a fresh construction gives the identical result
    (the elastic-restart determinism the engine tests rely on)."""
    g1 = topo.make_topology_schedule("erdos_resampled", 8, period=3, seed=5).grown(11)
    g2 = topo.make_topology_schedule("erdos_resampled", 8, period=3, seed=5).grown(11)
    for x, y in zip(g1.combiners, g2.combiners):
        np.testing.assert_array_equal(x, y)
    for t, a in enumerate(g1.combiners):
        assert topo.is_doubly_stochastic(a), t


def test_derive_seed_is_stable_and_stream_separated():
    assert topo.derive_seed(3, 1) == topo.derive_seed(3, 1)
    assert topo.derive_seed(3, 1) != topo.derive_seed(3, 2)
    assert topo.derive_seed(3, 1) != topo.derive_seed(4, 1)


# ---------------------------------------------------------------------------
# grow-preserving erdos sampler (topology-aware elastic growth)
# ---------------------------------------------------------------------------


def test_erdos_renyi_grow_preserves_existing_neighborhoods():
    old = topo.erdos_renyi_adjacency(8, p=0.4, seed=2)
    new = topo.erdos_renyi_grow(old, 12, p=0.4, seed=9)
    # the old agents' subgraph is untouched — no rewiring mid-stream
    np.testing.assert_array_equal(new[:8, :8], old)
    assert topo.is_connected(new)
    assert topo.is_doubly_stochastic(topo.metropolis_weights(new))
    # degenerate no-growth case
    np.testing.assert_array_equal(topo.erdos_renyi_grow(old, 8), old)
    with pytest.raises(ValueError):
        topo.erdos_renyi_grow(old, 4)


def test_grown_schedule_preserves_erdos_neighborhoods_per_step():
    s = topo.make_topology_schedule("erdos_resampled", 6, period=3, seed=7)
    g = s.grown(9)
    assert g.n == 9 and g.period == 3 and g.kinds == s.kinds
    for old, new in zip(s.adjacencies, g.adjacencies):
        np.testing.assert_array_equal(new[:6, :6], old)


def test_grown_alternating_rederives_structured_kinds():
    s = topo.make_topology_schedule("alternating:ring_metropolis,torus", 6)
    g = s.grown(8)
    np.testing.assert_allclose(g.combiners[0], topo.make_topology("ring_metropolis", 8))
    np.testing.assert_allclose(g.combiners[1], topo.make_topology("torus", 8))
