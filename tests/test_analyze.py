"""Tests for tools/analyze: each rule fires exactly once on its known-bad
fixture, the repo itself is clean, and the jaxpr layer's wire-byte
accounting reproduces the engine's analytic numbers (the chain:3level
row of benchmarks/gossip_modes.py).
"""

import math
import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

from tools.analyze import all_rules, run_repo  # noqa: E402
from tools.analyze import (  # noqa: E402
    rules_ast,
    rules_budget,
    rules_jaxpr,
    rules_recompile,
    rules_replication,
)
from tools.analyze.report import Finding, render_github, render_json  # noqa: E402
from tools.analyze.walker import filter_suppressed  # noqa: E402

FIXTURES = ROOT / "tests" / "fixtures" / "analyze"


def _load_fixture(name):
    import importlib.util

    spec = importlib.util.spec_from_file_location(name, FIXTURES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _trace_fixture(mod):
    import jax
    import jax.numpy as jnp

    args = (jnp.zeros((2, 4), jnp.float32),)
    return rules_jaxpr.trace_check(
        mod.fn, args, mod.AXIS_ENV, file="tests/fixtures/analyze"
    )


# ---------------------------------------------------------------------------
# jaxpr rules on known-bad fixtures
# ---------------------------------------------------------------------------


def test_cond_mismatch_fires_parity_once():
    mod = _load_fixture("cond_mismatch")
    jaxpr, findings = _trace_fixture(mod)
    assert not findings
    ck = rules_jaxpr.check_jaxpr(jaxpr, dict(mod.AXIS_ENV))
    rules = [f.rule for f in ck.findings]
    assert rules == ["cond-collective-parity"]


def test_bad_permutation_fires_table_once():
    mod = _load_fixture("bad_permutation")
    jaxpr, findings = _trace_fixture(mod)
    assert not findings
    ck = rules_jaxpr.check_jaxpr(jaxpr, dict(mod.AXIS_ENV))
    rules = [f.rule for f in ck.findings]
    assert rules == ["ppermute-table"]


def test_branch_pytree_fires_structure_once():
    mod = _load_fixture("branch_pytree")
    jaxpr, findings = _trace_fixture(mod)
    assert jaxpr is None
    assert [f.rule for f in findings] == ["branch-structure"]


def test_good_permutation_is_clean():
    import jax
    import jax.numpy as jnp

    def fn(x):
        return jax.lax.ppermute(x, "model", [(0, 1), (1, 0)])

    jaxpr, findings = rules_jaxpr.trace_check(
        fn, (jnp.zeros((2, 4), jnp.float32),), (("model", 2),), file="t"
    )
    assert not findings
    ck = rules_jaxpr.check_jaxpr(jaxpr, {"model": 2})
    assert not ck.findings


def test_unreadable_gate_fires_wire_bytes_once():
    # cond branches inside a scan ship different byte counts, but the
    # selector is a traced input (not a rem-of-counter gate): the firing
    # fraction is not statically readable -> wire-bytes fires
    import jax
    import jax.numpy as jnp

    def fn(x, sel):
        def body(carry, _):
            def fire(v):
                return jax.lax.ppermute(v, "model", [(0, 1), (1, 0)])

            def hold(v):
                return v

            return jax.lax.cond(sel, fire, hold, carry), None

        y, _ = jax.lax.scan(body, x, None, length=2)
        return y

    jaxpr, findings = rules_jaxpr.trace_check(
        fn, (jnp.zeros((2, 4), jnp.float32), jnp.asarray(True)),
        (("model", 2),), file="t",
    )
    assert not findings
    ck = rules_jaxpr.check_jaxpr(jaxpr, {"model": 2})
    assert [f.rule for f in ck.findings] == ["wire-bytes"]


def test_missing_trace_case_fires_coverage(monkeypatch):
    from repro.core import distributed as D

    monkeypatch.setattr(D, "mode_trace_cases", lambda: [])
    findings = rules_jaxpr.run(ROOT)
    assert {f.rule for f in findings} == {"trace-coverage"}
    assert len(findings) == len(D.MODES)


# ---------------------------------------------------------------------------
# AST rules on known-bad fixtures
# ---------------------------------------------------------------------------


def test_bad_lock_fires_once():
    fs = rules_ast.check_lock_discipline(FIXTURES / "bad_lock.py", ROOT)
    assert [f.rule for f in fs] == ["lock-discipline"]
    assert "counter" in fs[0].message


def test_bad_router_lock_fires_once_and_nested_with_guards():
    """The serving-plane router declares the same _GUARDED_BY_LOCK contract
    as the service, so the registry-driven rule covers it with no rule
    change — and a `with self._lock:` nested directly inside another with
    statement (Router.submit's shape) counts as guarded (regression for the
    traversal flattening nested withs)."""
    fs = rules_ast.check_lock_discipline(FIXTURES / "bad_router_lock.py", ROOT)
    assert [f.rule for f in fs] == ["lock-discipline"]
    assert "rerouted" in fs[0].message
    assert "RouterLike.bad" in fs[0].message


def test_bad_exec_fires_once():
    fs = rules_ast.check_exec_lock(FIXTURES / "bad_exec.py", ROOT)
    assert [f.rule for f in fs] == ["exec-lock"]
    assert "solve" in fs[0].message


def test_bad_axis_fires_once():
    fs = rules_ast.check_axis_literals(FIXTURES / "bad_axis.py", ROOT)
    assert [f.rule for f in fs] == ["axis-literal"]
    assert "'model'" in fs[0].message


def test_bad_mode_registry_fires_once():
    fs = rules_ast.check_mode_registry(
        FIXTURES / "bad_mode_registry.py", ROOT / "tests", ROOT
    )
    assert [f.rule for f in fs] == ["mode-registry"]
    assert "topology_schedule" in fs[0].message


# ---------------------------------------------------------------------------
# docs rules on a known-bad synthetic tree (one firing per rule)
# ---------------------------------------------------------------------------


def test_doc_rules_each_fire_once(tmp_path):
    from tools.analyze import rules_docs
    from tools.analyze.report import counts_by_rule

    (tmp_path / "docs").mkdir()
    sr = tmp_path / "src" / "repro"
    (sr / "runtime").mkdir(parents=True)
    (sr / "core").mkdir()
    (sr / "launch").mkdir()
    (sr / "runtime" / "dist.py").write_text(
        '"""m."""\n\n\ndef documented():\n    """d."""\n\n\n'
        "def bare():\n    pass\n"
    )
    (sr / "core" / "distributed.py").write_text('"""m."""\n')
    (sr / "core" / "topology.py").write_text(
        '"""m."""\nGRAPH_KINDS = ("ring",)\nLEVEL_WIRES = ("fp32", "q8")\n'
    )
    (sr / "launch" / "serve_dict.py").write_text(
        '"""m."""\nimport argparse\nap = argparse.ArgumentParser()\n'
        'ap.add_argument("--levels")\n'
    )
    (tmp_path / "README.md").write_text(
        "[broken](missing.md)\n\n"
        "```\npython -m repro.launch.serve_dict --fake --levels bogus\n```\n"
    )
    counts = counts_by_rule(rules_docs.run(tmp_path))
    assert counts == {
        "doc-links": 1,        # missing.md does not resolve
        "doc-docstrings": 1,   # bare() has no docstring
        "doc-cli-flags": 1,    # --fake is not an argparse flag
        "doc-levels-spec": 1,  # 'bogus' is not a graph kind
    }


# ---------------------------------------------------------------------------
# clean-repo regression + report formats
# ---------------------------------------------------------------------------


def test_repo_is_clean_ast_and_docs():
    findings, rules, _ = run_repo(ROOT, with_jaxpr=False)
    assert len(rules) >= 6
    assert findings == [], "\n".join(f.location() + " " + f.message for f in findings)


def test_repo_is_clean_jaxpr():
    findings = rules_jaxpr.run(ROOT)
    kept, _ = filter_suppressed(findings, ROOT)
    assert kept == [], "\n".join(f.location() + " " + f.message for f in kept)


def test_all_rules_registered():
    rules = all_rules(with_jaxpr=True)
    assert len(rules) == len(set(rules)) >= 24
    assert "push-weight-pairing" in rules
    assert "cond-collective-parity" in rules and "doc-links" in rules
    for r in rules_replication.RULES + rules_recompile.RULES + rules_budget.RULES:
        assert r in rules
    # the stdlib-only subset drops the jax layers but keeps the recompile
    # AST rules (they need no jax import)
    lite = all_rules(with_jaxpr=False)
    assert "weak-literal-carry" in lite
    assert "out-spec-replication" not in lite


def test_report_formats():
    f = Finding("ppermute-table", "src/x.py", 7, "msg\nsecond line")
    gj = render_github([f])
    assert "::error file=src/x.py,line=7" in gj and "\n" not in gj.split("::error")[1]
    import json

    data = json.loads(render_json([f], ("ppermute-table",)))
    assert data["ok"] is False and data["findings"][0]["line"] == 7


# ---------------------------------------------------------------------------
# wire-byte cross-check: the jaxpr-measured bytes equal the engine's
# analytic wire_bytes_per_iter — the chain:3level row matches the numbers
# benchmarks/gossip_modes.py reports
# ---------------------------------------------------------------------------


def _trace_case(case, batch=8, m=32):
    from repro.core import distributed as D

    sizes = dict(case.axis_sizes)
    coder, jaxpr = D.abstract_trace(case.cfg, case.axis_sizes, batch=batch, m=m)
    ck = rules_jaxpr.check_jaxpr(
        jaxpr, sizes,
        in_varying=[frozenset(coder._agent_axes),
                    frozenset(case.cfg.data_axes), frozenset()],
    )
    b_loc = batch // int(math.prod(sizes[a] for a in case.cfg.data_axes))
    return coder, ck, dict(coder.wire_bytes_per_iter(b_loc, m))


def _case(name):
    from repro.core import distributed as D

    return next(c for c in D.mode_trace_cases() if c.name == name)


def test_chain_3level_wire_bytes():
    coder, ck, expected = _trace_case(_case("chain:3level"))
    assert not ck.findings
    # fp32 model level (B=8, M=32) = 4*8*32; q8 pod level stride 2 =
    # 8*(32+4)/2; q8 outer level stride 4 = 8*(32+4)/4
    assert expected == {"model": 1024.0, "pod": 144.0, "pod2": 72.0}
    assert ck.bytes_by_axis == pytest.approx(expected)


def test_ring_q8_wire_bytes():
    _, ck, expected = _trace_case(_case("ring_q8"))
    assert expected == {"model": 576.0}
    assert ck.bytes_by_axis == pytest.approx(expected)


def test_mode_trace_cases_cover_registry():
    from repro.core import distributed as D

    covered = {c.cfg.mode for c in D.mode_trace_cases()}
    assert covered == set(D.MODES)


# ---------------------------------------------------------------------------
# layer 3: replication-soundness rules on known-bad fixtures
# ---------------------------------------------------------------------------


def _replication_findings(name, args, in_varying):
    mod = _load_fixture(name)
    jaxpr, findings = rules_jaxpr.trace_check(
        mod.fn, args, mod.AXIS_ENV, file="tests/fixtures/analyze"
    )
    assert not findings
    return rules_replication.check_program(
        jaxpr, dict(mod.AXIS_ENV),
        out_meta=mod.OUT_META, in_varying=in_varying,
        agent_axes=mod.AGENT_AXES, program=mod.PROGRAM,
        label=name, file="tests/fixtures/analyze", root=ROOT,
    )


def test_missing_pmax_fires_step_size_once():
    import jax.numpy as jnp

    fs = _replication_findings(
        "missing_pmax", (jnp.zeros((8, 4), jnp.float32),),
        [frozenset({"model"})],
    )
    assert [f.rule for f in fs] == ["step-size-replication"]
    assert "pmax" in fs[0].message


def test_missing_psum_fires_out_spec_once():
    import jax.numpy as jnp

    fs = _replication_findings(
        "missing_psum_outspec",
        (jnp.zeros((8, 4), jnp.float32), jnp.zeros((2, 8), jnp.float32)),
        [frozenset({"model"}), frozenset({"data"})],
    )
    assert [f.rule for f in fs] == ["out-spec-replication"]
    assert "'W'" in fs[0].message and "data" in fs[0].message


def test_varying_gate_fires_once():
    import jax.numpy as jnp

    # both branches are collective-free, so layer 1's
    # cond-collective-parity stays silent — only varying-gate catches it
    fs = _replication_findings(
        "varying_gate", (jnp.zeros((2, 4), jnp.float32),), [frozenset()]
    )
    assert [f.rule for f in fs] == ["varying-gate"]


def test_bad_q8_pairing_fires_once():
    import jax
    import jax.numpy as jnp

    mod = _load_fixture("bad_q8_pairing")
    jaxpr, findings = _trace_fixture(mod)
    assert not findings
    fs = rules_replication.check_quant_pairing(
        jaxpr, label="bad_q8_pairing", file="tests/fixtures/analyze",
        root=ROOT,
    )
    assert [f.rule for f in fs] == ["quant-scale-pairing"]

    # paired payload+scale under the identical table is clean
    def good(x):
        q = jnp.asarray(x * 127.0, jnp.int8)
        table = [(0, 1), (1, 0)]
        q_in = jax.lax.ppermute(q, "model", table)
        s_in = jax.lax.ppermute(jnp.max(jnp.abs(x)), "model", table)
        return q_in.astype(jnp.float32) * s_in / 127.0

    jaxpr2, findings2 = rules_jaxpr.trace_check(
        good, (jnp.zeros((2, 4), jnp.float32),), (("model", 2),), file="t"
    )
    assert not findings2
    assert not rules_replication.check_quant_pairing(
        jaxpr2, label="good", file="t", root=ROOT
    )


def test_bad_push_unpaired_fires_once():
    import jax
    import jax.numpy as jnp

    mod = _load_fixture("bad_push_unpaired")
    jaxpr, findings = _trace_fixture(mod)
    assert not findings
    fs = rules_replication.check_push_pairing(
        jaxpr, label="bad_push_unpaired", file="tests/fixtures/analyze",
        root=ROOT,
    )
    assert [f.rule for f in fs] == ["push-weight-pairing"]
    assert "weight" in fs[0].message

    # payload + scalar weight under the identical table is clean
    def good(x):
        table = [(0, 1), (1, 0)]
        w = jnp.ones((), jnp.float32)
        v_in = jax.lax.ppermute(w * x, "model", table)
        w_in = jax.lax.ppermute(w, "model", table)
        return v_in / w_in

    jaxpr2, findings2 = rules_jaxpr.trace_check(
        good, (jnp.zeros((2, 4), jnp.float32),), (("model", 2),), file="t"
    )
    assert not findings2
    assert not rules_replication.check_push_pairing(
        jaxpr2, label="good", file="t", root=ROOT
    )


def test_unreduced_mu_regression_is_caught(monkeypatch):
    # THE acceptance criterion: re-introducing the PR 2 bug (dropping the
    # pmax from _safe_mu_local) must be statically impossible — every
    # adaptive gossip mode's mu program flags step-size-replication.
    import jax
    import jax.numpy as jnp
    from repro.core import distributed as D
    from repro.core.inference import power_sigma2

    def bad_mu(res, reg, W_loc, axis):
        c_f = res.grad_fstar(jnp.ones((1,), W_loc.dtype))[0]
        n_agents = jax.lax.psum(1, axis)
        sig2_local = power_sigma2(W_loc)  # NO pmax — the PR 2 regression
        return 0.9 / (c_f / n_agents + sig2_local / reg.delta)

    monkeypatch.setattr(D, "_safe_mu_local", bad_mu)
    findings = rules_replication.run(ROOT)
    assert {f.rule for f in findings} == {"step-size-replication"}
    # every non-exact trace case (exact/exact_fista use _safe_mu_exact)
    expected = sum(
        1 for c in D.mode_trace_cases()
        if c.cfg.mode not in ("exact", "exact_fista")
    )
    assert expected >= 15  # grew with push/push_q8 + the linkfail case
    assert len(findings) == expected


def test_repo_is_clean_replication():
    kept, _ = filter_suppressed(rules_replication.run(ROOT), ROOT)
    assert kept == [], "\n".join(f.location() + " " + f.message for f in kept)


# ---------------------------------------------------------------------------
# layer 3: recompile-hazard AST rules on known-bad fixtures
# ---------------------------------------------------------------------------


def _recompile_ast_findings(name):
    p = FIXTURES / f"{name}.py"
    fs = []
    fs += rules_recompile.check_weak_literal_carry(p, ROOT)
    fs += rules_recompile.check_asarray_dtype(p, ROOT)
    fs += rules_recompile.check_jit_cache_discipline(p, ROOT)
    fs += rules_recompile.check_scalar_closure(p, ROOT)
    return fs


def test_bad_weak_carry_fires_once():
    fs = _recompile_ast_findings("bad_weak_carry")
    assert [f.rule for f in fs] == ["weak-literal-carry"]


def test_bad_asarray_fires_once():
    fs = _recompile_ast_findings("bad_asarray")
    assert [f.rule for f in fs] == ["asarray-dtype"]


def test_bad_jit_hot_fires_once():
    fs = _recompile_ast_findings("bad_jit_hot")
    assert [f.rule for f in fs] == ["jit-cache-discipline"]


def test_bad_scalar_closure_fires_once():
    fs = _recompile_ast_findings("bad_scalar_closure")
    assert [f.rule for f in fs] == ["scalar-closure"]
    assert "mu" in fs[0].message


def test_repo_is_clean_recompile_ast():
    fs = rules_recompile.run_ast(ROOT)
    assert fs == [], "\n".join(f.location() + " " + f.message for f in fs)


def test_retrace_on_second_trace_fires_once():
    import jax
    import jax.numpy as jnp

    mod = _load_fixture("retrace_on_second_trace")
    f = mod.make()
    x = jnp.zeros((2,), jnp.float32)
    fs = rules_recompile.assert_no_retrace(
        f, (x, 2), (x, 3), label="fixture",
        file="tests/fixtures/analyze", root=ROOT,
    )
    assert [g.rule for g in fs] == ["recompile-budget"]
    assert "2 compile-cache" in fs[0].message

    # value-varied traced inputs on a well-behaved jit stay at one entry
    g = jax.jit(lambda v: v * 2.0)
    assert rules_recompile.assert_no_retrace(
        g, (x,), (x + 1.0,), label="clean", file="t", root=ROOT
    ) == []


# ---------------------------------------------------------------------------
# layer 3: cost-budget gate (pure compare logic; devices not needed)
# ---------------------------------------------------------------------------


def test_budget_drift_fires_once():
    import json

    budgets = json.loads((FIXTURES / "budget_drift.json").read_text())
    measured = {
        "ring": {"flops": 26471.0, "collective_bytes": 4104.0,
                 "compile_count": 1},
    }
    fs = rules_budget.compare(
        measured, budgets, file="tools/analyze/budgets.json", root=ROOT
    )
    assert [f.rule for f in fs] == ["cost-budget"]
    assert "flops" in fs[0].message and "--update-budgets" in fs[0].message


def test_budget_missing_and_stale_modes():
    budgets = {"modes": {"ring": {"flops": 1.0, "collective_bytes": 1.0,
                                  "compile_count": 1}}}
    rec = {"flops": 1.0, "collective_bytes": 1.0, "compile_count": 1}
    # unpinned measured mode -> missing-budget finding
    fs = rules_budget.compare(
        {"ring": rec, "new_mode": rec}, budgets, file="b", root=ROOT
    )
    assert [f.rule for f in fs] == ["cost-budget"]
    assert "new_mode" in fs[0].message
    # pinned mode the trace matrix no longer produces -> stale finding
    fs = rules_budget.compare({}, budgets, file="b", root=ROOT)
    assert [f.rule for f in fs] == ["cost-budget"]
    assert "stale" in fs[0].message


def test_budget_compile_count_is_exact():
    budgets = {"modes": {"ring": {"flops": 100.0, "collective_bytes": 8.0,
                                  "compile_count": 1}}}
    # 1% flops drift is inside REL_TOL; compile_count has NO tolerance
    fs = rules_budget.compare(
        {"ring": {"flops": 101.0, "collective_bytes": 8.0,
                  "compile_count": 2}},
        budgets, file="b", root=ROOT,
    )
    assert [f.rule for f in fs] == ["cost-budget"]
    assert "compile_count" in fs[0].message


def test_budgets_json_covers_trace_matrix():
    from repro.core import distributed as D

    budgets = rules_budget.load_budgets(ROOT)
    assert budgets, "tools/analyze/budgets.json must be committed"
    assert set(budgets["modes"]) == {c.name for c in D.mode_trace_cases()}
    for name, rec in budgets["modes"].items():
        # the ONE-compiled-program invariant is pinned for every mode
        assert rec["compile_count"] == 1, name


# ---------------------------------------------------------------------------
# suppression: allow(rule: reason) + bare-allow rejection for layer 3
# ---------------------------------------------------------------------------


def test_layer3_suppression_requires_reason(tmp_path):
    src = tmp_path / "m.py"
    src.write_text(
        "x = 1  # analyze: allow(cost-budget)\n"
        "y = 2  # analyze: allow(cost-budget: probe intentionally re-pinned)\n"
        "z = 3  # analyze: allow(ppermute-table)\n"
    )
    fs = [
        Finding("cost-budget", "m.py", 1, "bare allow must NOT suppress"),
        Finding("cost-budget", "m.py", 2, "reasoned allow suppresses"),
        Finding("ppermute-table", "m.py", 3, "legacy rule: bare is fine"),
    ]
    kept, suppressed = filter_suppressed(fs, tmp_path)
    assert [f.line for f in kept] == [1]
    assert [f.line for f in suppressed] == [2, 3]


def test_suppression_comma_list_with_reasons(tmp_path):
    src = tmp_path / "m.py"
    src.write_text(
        "# analyze: allow(axis-literal, scalar-closure: probe helper)\n"
        "x = 1\n"
    )
    fs = [
        Finding("axis-literal", "m.py", 2, "bare, legacy -> suppressed"),
        Finding("scalar-closure", "m.py", 2, "reasoned, layer 3 -> suppressed"),
        Finding("asarray-dtype", "m.py", 2, "not listed -> kept"),
    ]
    kept, suppressed = filter_suppressed(fs, tmp_path)
    assert [f.rule for f in kept] == ["asarray-dtype"]
    assert {f.rule for f in suppressed} == {"axis-literal", "scalar-closure"}


def test_render_json_reports_suppression_counts():
    import json

    sup = [Finding("cost-budget", "a.py", 1, "m"),
           Finding("cost-budget", "a.py", 9, "m")]
    data = json.loads(render_json([], ("cost-budget",), sup))
    assert data["ok"] is True
    assert data["suppressed"] == {"total": 2, "by_rule": {"cost-budget": 2}}


# ---------------------------------------------------------------------------
# full CLI: the committed repo analyzes clean, including the dynamic
# recompile/cost gates (the "0 retraces across all registry modes"
# acceptance run) — subprocess so jax gets 8 forced host devices
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_analyze_cli_clean_including_dynamic_gates():
    import json
    import subprocess

    r = subprocess.run(
        [sys.executable, "-m", "tools.analyze", "--json"],
        cwd=ROOT, capture_output=True, text=True, timeout=600,
    )
    assert r.returncode == 0, r.stdout + "\n" + r.stderr
    data = json.loads(r.stdout)
    assert data["ok"] is True and data["findings"] == []
    assert len(data["rules"]) >= 23
