"""Tests for tools/analyze: each rule fires exactly once on its known-bad
fixture, the repo itself is clean, and the jaxpr layer's wire-byte
accounting reproduces the engine's analytic numbers (the chain:3level
row of benchmarks/gossip_modes.py).
"""

import math
import pathlib
import sys

import pytest

ROOT = pathlib.Path(__file__).resolve().parents[1]
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

from tools.analyze import all_rules, run_repo  # noqa: E402
from tools.analyze import rules_ast, rules_jaxpr  # noqa: E402
from tools.analyze.report import Finding, render_github, render_json  # noqa: E402
from tools.analyze.walker import filter_suppressed  # noqa: E402

FIXTURES = ROOT / "tests" / "fixtures" / "analyze"


def _load_fixture(name):
    import importlib.util

    spec = importlib.util.spec_from_file_location(name, FIXTURES / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _trace_fixture(mod):
    import jax
    import jax.numpy as jnp

    args = (jnp.zeros((2, 4), jnp.float32),)
    return rules_jaxpr.trace_check(
        mod.fn, args, mod.AXIS_ENV, file="tests/fixtures/analyze"
    )


# ---------------------------------------------------------------------------
# jaxpr rules on known-bad fixtures
# ---------------------------------------------------------------------------


def test_cond_mismatch_fires_parity_once():
    mod = _load_fixture("cond_mismatch")
    jaxpr, findings = _trace_fixture(mod)
    assert not findings
    ck = rules_jaxpr.check_jaxpr(jaxpr, dict(mod.AXIS_ENV))
    rules = [f.rule for f in ck.findings]
    assert rules == ["cond-collective-parity"]


def test_bad_permutation_fires_table_once():
    mod = _load_fixture("bad_permutation")
    jaxpr, findings = _trace_fixture(mod)
    assert not findings
    ck = rules_jaxpr.check_jaxpr(jaxpr, dict(mod.AXIS_ENV))
    rules = [f.rule for f in ck.findings]
    assert rules == ["ppermute-table"]


def test_branch_pytree_fires_structure_once():
    mod = _load_fixture("branch_pytree")
    jaxpr, findings = _trace_fixture(mod)
    assert jaxpr is None
    assert [f.rule for f in findings] == ["branch-structure"]


def test_good_permutation_is_clean():
    import jax
    import jax.numpy as jnp

    def fn(x):
        return jax.lax.ppermute(x, "model", [(0, 1), (1, 0)])

    jaxpr, findings = rules_jaxpr.trace_check(
        fn, (jnp.zeros((2, 4), jnp.float32),), (("model", 2),), file="t"
    )
    assert not findings
    ck = rules_jaxpr.check_jaxpr(jaxpr, {"model": 2})
    assert not ck.findings


def test_unreadable_gate_fires_wire_bytes_once():
    # cond branches inside a scan ship different byte counts, but the
    # selector is a traced input (not a rem-of-counter gate): the firing
    # fraction is not statically readable -> wire-bytes fires
    import jax
    import jax.numpy as jnp

    def fn(x, sel):
        def body(carry, _):
            def fire(v):
                return jax.lax.ppermute(v, "model", [(0, 1), (1, 0)])

            def hold(v):
                return v

            return jax.lax.cond(sel, fire, hold, carry), None

        y, _ = jax.lax.scan(body, x, None, length=2)
        return y

    jaxpr, findings = rules_jaxpr.trace_check(
        fn, (jnp.zeros((2, 4), jnp.float32), jnp.asarray(True)),
        (("model", 2),), file="t",
    )
    assert not findings
    ck = rules_jaxpr.check_jaxpr(jaxpr, {"model": 2})
    assert [f.rule for f in ck.findings] == ["wire-bytes"]


def test_missing_trace_case_fires_coverage(monkeypatch):
    from repro.core import distributed as D

    monkeypatch.setattr(D, "mode_trace_cases", lambda: [])
    findings = rules_jaxpr.run(ROOT)
    assert {f.rule for f in findings} == {"trace-coverage"}
    assert len(findings) == len(D.MODES)


# ---------------------------------------------------------------------------
# AST rules on known-bad fixtures
# ---------------------------------------------------------------------------


def test_bad_lock_fires_once():
    fs = rules_ast.check_lock_discipline(FIXTURES / "bad_lock.py", ROOT)
    assert [f.rule for f in fs] == ["lock-discipline"]
    assert "counter" in fs[0].message


def test_bad_exec_fires_once():
    fs = rules_ast.check_exec_lock(FIXTURES / "bad_exec.py", ROOT)
    assert [f.rule for f in fs] == ["exec-lock"]
    assert "solve" in fs[0].message


def test_bad_axis_fires_once():
    fs = rules_ast.check_axis_literals(FIXTURES / "bad_axis.py", ROOT)
    assert [f.rule for f in fs] == ["axis-literal"]
    assert "'model'" in fs[0].message


def test_bad_mode_registry_fires_once():
    fs = rules_ast.check_mode_registry(
        FIXTURES / "bad_mode_registry.py", ROOT / "tests", ROOT
    )
    assert [f.rule for f in fs] == ["mode-registry"]
    assert "topology_schedule" in fs[0].message


# ---------------------------------------------------------------------------
# docs rules on a known-bad synthetic tree (one firing per rule)
# ---------------------------------------------------------------------------


def test_doc_rules_each_fire_once(tmp_path):
    from tools.analyze import rules_docs
    from tools.analyze.report import counts_by_rule

    (tmp_path / "docs").mkdir()
    sr = tmp_path / "src" / "repro"
    (sr / "runtime").mkdir(parents=True)
    (sr / "core").mkdir()
    (sr / "launch").mkdir()
    (sr / "runtime" / "dist.py").write_text(
        '"""m."""\n\n\ndef documented():\n    """d."""\n\n\n'
        "def bare():\n    pass\n"
    )
    (sr / "core" / "distributed.py").write_text('"""m."""\n')
    (sr / "core" / "topology.py").write_text(
        '"""m."""\nGRAPH_KINDS = ("ring",)\nLEVEL_WIRES = ("fp32", "q8")\n'
    )
    (sr / "launch" / "serve_dict.py").write_text(
        '"""m."""\nimport argparse\nap = argparse.ArgumentParser()\n'
        'ap.add_argument("--levels")\n'
    )
    (tmp_path / "README.md").write_text(
        "[broken](missing.md)\n\n"
        "```\npython -m repro.launch.serve_dict --fake --levels bogus\n```\n"
    )
    counts = counts_by_rule(rules_docs.run(tmp_path))
    assert counts == {
        "doc-links": 1,        # missing.md does not resolve
        "doc-docstrings": 1,   # bare() has no docstring
        "doc-cli-flags": 1,    # --fake is not an argparse flag
        "doc-levels-spec": 1,  # 'bogus' is not a graph kind
    }


# ---------------------------------------------------------------------------
# clean-repo regression + report formats
# ---------------------------------------------------------------------------


def test_repo_is_clean_ast_and_docs():
    findings, rules, _ = run_repo(ROOT, with_jaxpr=False)
    assert len(rules) >= 6
    assert findings == [], "\n".join(f.location() + " " + f.message for f in findings)


def test_repo_is_clean_jaxpr():
    findings = rules_jaxpr.run(ROOT)
    kept, _ = filter_suppressed(findings, ROOT)
    assert kept == [], "\n".join(f.location() + " " + f.message for f in kept)


def test_all_rules_registered():
    rules = all_rules(with_jaxpr=True)
    assert len(rules) == len(set(rules)) >= 13
    assert "cond-collective-parity" in rules and "doc-links" in rules


def test_report_formats():
    f = Finding("ppermute-table", "src/x.py", 7, "msg\nsecond line")
    gj = render_github([f])
    assert "::error file=src/x.py,line=7" in gj and "\n" not in gj.split("::error")[1]
    import json

    data = json.loads(render_json([f], ("ppermute-table",)))
    assert data["ok"] is False and data["findings"][0]["line"] == 7


# ---------------------------------------------------------------------------
# wire-byte cross-check: the jaxpr-measured bytes equal the engine's
# analytic wire_bytes_per_iter — the chain:3level row matches the numbers
# benchmarks/gossip_modes.py reports
# ---------------------------------------------------------------------------


def _trace_case(case, batch=8, m=32):
    from repro.core import distributed as D

    sizes = dict(case.axis_sizes)
    coder, jaxpr = D.abstract_trace(case.cfg, case.axis_sizes, batch=batch, m=m)
    ck = rules_jaxpr.check_jaxpr(
        jaxpr, sizes,
        in_varying=[frozenset(coder._agent_axes),
                    frozenset(case.cfg.data_axes), frozenset()],
    )
    b_loc = batch // int(math.prod(sizes[a] for a in case.cfg.data_axes))
    return coder, ck, dict(coder.wire_bytes_per_iter(b_loc, m))


def _case(name):
    from repro.core import distributed as D

    return next(c for c in D.mode_trace_cases() if c.name == name)


def test_chain_3level_wire_bytes():
    coder, ck, expected = _trace_case(_case("chain:3level"))
    assert not ck.findings
    # fp32 model level (B=8, M=32) = 4*8*32; q8 pod level stride 2 =
    # 8*(32+4)/2; q8 outer level stride 4 = 8*(32+4)/4
    assert expected == {"model": 1024.0, "pod": 144.0, "pod2": 72.0}
    assert ck.bytes_by_axis == pytest.approx(expected)


def test_ring_q8_wire_bytes():
    _, ck, expected = _trace_case(_case("ring_q8"))
    assert expected == {"model": 576.0}
    assert ck.bytes_by_axis == pytest.approx(expected)


def test_mode_trace_cases_cover_registry():
    from repro.core import distributed as D

    covered = {c.cfg.mode for c in D.mode_trace_cases()}
    assert covered == set(D.MODES)
