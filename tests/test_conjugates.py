"""Property tests for the conjugate-function machinery (paper Tables I-II,
Appendix A) — the mathematical foundation of the dual protocol."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, strategies as st

from repro.core.conjugates import (
    make_elastic_net,
    make_huber_residual,
    make_l2_residual,
    make_nonneg_elastic_net,
    make_task,
    soft_threshold,
    soft_threshold_pos,
)

settings.register_profile("fast", max_examples=25, deadline=None)
settings.load_profile("fast")

floats = st.floats(-10.0, 10.0, allow_nan=False, allow_infinity=False)


# ---------------------------------------------------------------------------
# Thresholding operators (Fig. 3)
# ---------------------------------------------------------------------------


@given(st.lists(floats, min_size=1, max_size=16), st.floats(0.01, 5.0))
def test_soft_threshold_properties(xs, lam):
    x = jnp.asarray(xs)
    t = soft_threshold(x, lam)
    assert bool(jnp.all(jnp.abs(t) <= jnp.abs(x) + 1e-6))  # shrinkage
    assert bool(jnp.all(t * x >= -1e-6))  # sign preservation
    big = jnp.abs(x) > lam
    # beyond the threshold the shrink is exactly lam
    np.testing.assert_allclose(
        np.abs(np.asarray(t))[np.asarray(big)],
        (np.abs(np.asarray(x)) - lam)[np.asarray(big)],
        rtol=1e-5, atol=1e-6,
    )
    assert bool(jnp.all(jnp.where(~big, t == 0, True)))


@given(st.lists(floats, min_size=1, max_size=16), st.floats(0.01, 5.0))
def test_one_sided_threshold(xs, lam):
    x = jnp.asarray(xs)
    t = soft_threshold_pos(x, lam)
    assert bool(jnp.all(t >= 0))
    np.testing.assert_allclose(np.asarray(t), np.maximum(np.asarray(x) - lam, 0.0), rtol=1e-6)


# ---------------------------------------------------------------------------
# Fenchel-Young (in)equality: h*(v) = v.ystar - h(ystar) >= v.y - h(y)
# ---------------------------------------------------------------------------


@given(
    st.integers(1, 12),
    st.floats(0.05, 2.0),
    st.floats(0.05, 2.0),
    st.integers(0, 2**31 - 1),
    st.booleans(),
)
def test_fenchel_young_elastic_net(k, gamma, delta, seed, nonneg):
    reg = make_nonneg_elastic_net(gamma, delta) if nonneg else make_elastic_net(gamma, delta)
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=(k,)), jnp.float32)
    ystar = reg.ystar(v)
    hstar = reg.hstar(v)
    val_at_star = jnp.dot(v, ystar) - reg.h(ystar)
    # equality at the maximizer (closed forms from Appendix A)
    np.testing.assert_allclose(float(hstar), float(val_at_star), rtol=1e-4, atol=1e-5)
    # inequality at random feasible y
    for _ in range(5):
        y = jnp.asarray(rng.normal(size=(k,)), jnp.float32)
        if nonneg:
            y = jnp.abs(y)
        assert float(jnp.dot(v, y) - reg.h(y)) <= float(hstar) + 1e-4


@given(st.integers(1, 12), st.integers(0, 2**31 - 1))
def test_fenchel_young_l2(m, seed):
    res = make_l2_residual()
    rng = np.random.default_rng(seed)
    nu = jnp.asarray(rng.normal(size=(m,)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(m,)), jnp.float32)
    # f(u) + f*(nu) >= nu.u, equality at u = nu (since grad f*(nu) = nu)
    assert float(res.f(u) + res.fstar(nu)) >= float(jnp.dot(nu, u)) - 1e-5
    np.testing.assert_allclose(
        float(res.f(nu) + res.fstar(nu)), float(jnp.dot(nu, nu)), rtol=1e-5
    )


@given(st.integers(1, 12), st.floats(0.05, 1.0), st.integers(0, 2**31 - 1))
def test_fenchel_young_huber(m, eta, seed):
    res = make_huber_residual(eta)
    rng = np.random.default_rng(seed)
    nu = jnp.clip(jnp.asarray(rng.normal(size=(m,)), jnp.float32), -1.0, 1.0)
    for _ in range(5):
        u = jnp.asarray(rng.normal(size=(m,)) * 3, jnp.float32)
        assert float(res.f(u) + res.fstar(nu)) >= float(jnp.dot(nu, u)) - 1e-4
    # the maximizer of nu.u - f(u) is u = eta*nu (interior of |nu|<=1)
    u_star = eta * nu
    np.testing.assert_allclose(
        float(jnp.dot(nu, u_star) - res.f(u_star)), float(res.fstar(nu)), rtol=1e-4, atol=1e-5
    )


def test_huber_projection():
    res = make_huber_residual(0.2)
    nu = jnp.asarray([-3.0, -0.5, 0.0, 0.7, 42.0])
    np.testing.assert_allclose(
        np.asarray(res.project_dual(nu)), [-1.0, -0.5, 0.0, 0.7, 1.0]
    )
    assert res.bounded_dual and not res.strongly_convex


# ---------------------------------------------------------------------------
# ystar is the gradient of hstar (Danskin) — finite-difference check
# ---------------------------------------------------------------------------


@given(st.floats(0.05, 2.0), st.floats(0.1, 2.0), st.integers(0, 2**31 - 1))
def test_ystar_is_grad_hstar(gamma, delta, seed):
    reg = make_elastic_net(gamma, delta)
    rng = np.random.default_rng(seed)
    v = jnp.asarray(rng.normal(size=(6,)), jnp.float32)
    g_auto = jax.grad(lambda vv: reg.hstar(vv))(v)
    np.testing.assert_allclose(np.asarray(g_auto), np.asarray(reg.ystar(v)), rtol=2e-3, atol=2e-3)


def test_task_registry():
    for name in ("sparse_svd", "bi_clustering", "nmf", "nmf_huber"):
        res, reg = make_task(name)
        assert res is not None and reg is not None
    with pytest.raises(KeyError):
        make_task("nope")
